//! The coordinator proper: execute a batch of [`QueryRequest`]s under a
//! policy.
//!
//! Owns the machine, the flow engine, and a per-kind demand cache.
//! Responsible for the stripe-offset assignment (each concurrent query's
//! own arrays land on rotated channels — see
//! [`crate::alg::bfs::bfs_run_offset`]) and for demand caching: an
//! analysis that declares [`crate::alg::Analysis::cacheable_demand`]
//! (parameter-free kinds like connected components) has its expensive
//! functional execution run once per cache key; each further instance is a
//! cheap channel rotation of the cached phases.

use crate::alg::Analysis;
use crate::coordinator::admission::ContextLedger;
use crate::coordinator::batch::{BatchConfig, BatchPlan};
use crate::coordinator::request::QueryRequest;
use crate::graph::csr::Csr;
use crate::graph::view::GraphView;
use crate::sim::demand::PhaseDemand;
use crate::sim::flow::{FlowSim, OnFull, QuerySpec, ShareWeights};
use crate::sim::machine::Machine;
use crate::sim::preempt::PreemptPolicy;
use crate::sim::trace::{NullSink, TraceSink};
use std::collections::HashMap;

use super::metrics::RunReport;

/// Execution policy for a batch of queries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// One query at a time, in submission order (the paper's baseline arm).
    Sequential,
    /// All queries at once, no admission control — the paper's concurrent
    /// arm ("without any explicit scheduling or allocation of resources").
    /// Exceeding the machine's thread-context memory is *fatal* on the real
    /// Pathfinder; here `run` returns an error instead.
    Concurrent,
    /// Concurrent with byte-exact admission control at the machine's
    /// thread-context capacity: the overload behavior a production
    /// deployment would choose. The wait queue is priority-ordered with
    /// anti-starvation aging; see [`crate::sim::flow::Admission`].
    ConcurrentAdmitted {
        /// Overload behavior when an arrival cannot start immediately.
        on_full: OnFull,
        /// Fair-share weights dividing bandwidth among *running* queries
        /// by priority class (flat = plain max-min, the PR 2 behavior).
        weights: ShareWeights,
        /// Checkpoint preemption of running Batch work under Interactive
        /// pressure (None = disabled; see [`crate::sim::preempt`]).
        preempt: Option<PreemptPolicy>,
    },
}

impl Policy {
    /// Admitted execution with flat weights and no preemption — PR 2's
    /// `ConcurrentAdmitted` behavior under one name.
    pub fn admitted(on_full: OnFull) -> Self {
        Policy::ConcurrentAdmitted { on_full, weights: ShareWeights::flat(), preempt: None }
    }

    /// Report label. `ctx_capacity_bytes` is the effective admission
    /// budget, included so reports on differently-sized machines (or
    /// what-if capacities) are distinguishable; non-flat weights and
    /// preemption are appended so runs with different sharing policies
    /// never collide in a report.
    pub fn label(&self, ctx_capacity_bytes: u64) -> String {
        let cap_mib = ctx_capacity_bytes >> 20;
        match self {
            Policy::Sequential => "sequential".into(),
            Policy::Concurrent => "concurrent".into(),
            Policy::ConcurrentAdmitted { on_full, weights, preempt } => {
                let mode = match on_full {
                    OnFull::Queue => "queue".to_string(),
                    OnFull::Reject => "reject".to_string(),
                    OnFull::Shed { max_waiting } => format!("shed<={max_waiting}"),
                };
                let mut out = format!("concurrent({mode}, cap={cap_mib}MiB");
                if !weights.is_flat() {
                    out.push_str(&format!(", w={}", weights.label()));
                }
                out.push(')');
                if preempt.is_some() {
                    out.push_str("+preempt");
                }
                out
            }
        }
    }
}

/// The concurrent-query coordinator for one graph on one machine.
pub struct Coordinator<'g> {
    g: &'g Csr,
    machine: Machine,
    sim: FlowSim,
    /// Cached stripe-offset-0 demand per analysis cache key (computed on
    /// first use; see [`crate::alg::Analysis::cacheable_demand`]).
    demand_cache: std::cell::RefCell<HashMap<String, Vec<PhaseDemand>>>,
}

impl<'g> Coordinator<'g> {
    pub fn new(g: &'g Csr, machine: Machine) -> Self {
        let sim = FlowSim::new(machine.clone());
        Coordinator { g, machine, sim, demand_cache: std::cell::RefCell::new(HashMap::new()) }
    }

    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    pub fn graph(&self) -> &Csr {
        self.g
    }

    /// Thread-context capacity of this machine, in default-footprint
    /// queries.
    pub fn capacity(&self) -> usize {
        self.machine.cfg.max_concurrent_queries()
    }

    /// Total thread-context memory of the machine (bytes).
    pub fn ctx_capacity_bytes(&self) -> u64 {
        self.machine.cfg.nodes as u64 * self.machine.cfg.ctx_mem_per_node_bytes
    }

    /// The coordinator's graph as a flat (epoch-0) view.
    pub fn view(&self) -> GraphView<'_> {
        GraphView::flat(self.g)
    }

    /// Thread-context memory the batch reserves if run fully concurrently
    /// (bytes): each analysis's declared footprint, or the machine default.
    pub fn ctx_demand_bytes(&self, requests: &[QueryRequest]) -> u64 {
        requests
            .iter()
            .map(|r| {
                r.analysis
                    .ctx_mem_bytes(self.view(), &self.machine)
                    .unwrap_or(self.machine.cfg.ctx_bytes_per_query)
            })
            .sum()
    }

    /// The byte ledger admitted execution runs against: the machine's
    /// whole thread-context memory, accounted per-query.
    pub fn ledger(&self) -> ContextLedger {
        ContextLedger::new(&self.machine.cfg)
    }

    /// THE epoch-aware preparation entry point: build engine-ready specs
    /// for a request batch against an explicit view/epoch snapshot.
    /// Request `i` gets id and stripe offset `base_id + i`; arrivals,
    /// priority, deadline and declared context footprint are taken from
    /// each request. Cacheable analyses hit the per-kind demand cache
    /// (epoch 0 only) and are rotated instead of re-executed.
    ///
    /// The static-graph callers pass `(self.view(), 0, requests, 0)`; the
    /// mutation lane prepares each arrival separately against its pinned
    /// epoch through [`Coordinator::prepare_one`], the single-request
    /// form this delegates to.
    pub fn prepare(
        &self,
        view: GraphView<'_>,
        epoch: u64,
        requests: &[QueryRequest],
        base_id: usize,
    ) -> Vec<QuerySpec> {
        requests
            .iter()
            .enumerate()
            .map(|(i, req)| self.prepare_one(view, epoch, req, base_id + i, base_id + i))
            .collect()
    }

    /// Single-request form of [`Coordinator::prepare`] — the mutation
    /// lane's path (DESIGN.md §Mutation): the service pins an epoch per
    /// arrival and prepares the query against that exact view, with
    /// non-contiguous ids from the merged timeline.
    ///
    /// The demand cache serves **epoch 0 only** (the coordinator's own
    /// immutable graph), keeping static-graph runs byte-identical to the
    /// pre-mutation cache behavior. Later epochs bypass the cache
    /// entirely: the cache outlives any one serve call while epoch
    /// numbering restarts per [`crate::graph::store::GraphStore`], so an
    /// epoch-tagged entry from one mutating run would silently serve a
    /// *different* edge set to the next.
    pub fn prepare_one(
        &self,
        view: GraphView<'_>,
        epoch: u64,
        req: &QueryRequest,
        id: usize,
        stripe_offset: usize,
    ) -> QuerySpec {
        let a = req.analysis.as_ref();
        let phases = match a.cacheable_demand() {
            Some(key) if epoch == 0 => {
                let mut cache = self.demand_cache.borrow_mut();
                let base =
                    cache.entry(key).or_insert_with(|| a.phases(view, &self.machine, 0));
                base.iter().map(|p| p.rotate_channels(stripe_offset)).collect()
            }
            _ => a.phases(view, &self.machine, stripe_offset),
        };
        QuerySpec {
            id,
            label: a.label(),
            phases,
            arrival_ns: req.arrival_ns,
            priority: req.priority,
            deadline_ns: req.deadline_ns,
            ctx_bytes: a
                .ctx_mem_bytes(view, &self.machine)
                .unwrap_or(self.machine.cfg.ctx_bytes_per_query),
        }
    }

    /// Prepare and execute a batch under `policy`, consuming the requests.
    /// The submission path a service front-end calls.
    pub fn submit(&self, requests: Vec<QueryRequest>, policy: Policy) -> anyhow::Result<RunReport> {
        self.run(&requests, policy)
    }

    /// The batching-aware submission path (DESIGN.md §Batching): coalesce
    /// compatible requests per `batch` into fused multi-source engine
    /// queries, run the fused plan under `policy`, and fan per-member
    /// latency/outcome accounting back out — the report has one record
    /// per ORIGINAL request. With nothing fusable (or `width = 1`) this
    /// degenerates to [`Coordinator::submit`] exactly.
    pub fn submit_batched(
        &self,
        requests: Vec<QueryRequest>,
        policy: Policy,
        batch: &BatchConfig,
    ) -> anyhow::Result<RunReport> {
        let plan = BatchPlan::build(&requests, None, batch)?;
        let specs = self.prepare(self.view(), 0, plan.fused(), 0);
        self.run_specs_grouped(&requests, plan.group_of(), plan.fused(), &specs, policy)
    }

    /// Execute `requests` under `policy` and report.
    pub fn run(&self, requests: &[QueryRequest], policy: Policy) -> anyhow::Result<RunReport> {
        let specs = self.prepare(self.view(), 0, requests, 0);
        self.run_specs(requests, &specs, policy)
    }

    /// Execute pre-prepared specs (lets the bench harness prepare once and
    /// run many sample points). One spec per request — the unbatched 1:1
    /// case of [`Coordinator::run_specs_grouped`].
    pub fn run_specs(
        &self,
        requests: &[QueryRequest],
        specs: &[QuerySpec],
        policy: Policy,
    ) -> anyhow::Result<RunReport> {
        let identity: Vec<usize> = (0..requests.len()).collect();
        self.run_specs_grouped(requests, &identity, requests, specs, policy)
    }

    /// Execute a (possibly fused) spec list under `policy` and fan the
    /// results back out to the original requests. `fused` and `specs` run
    /// 1:1 in the engine; `group_of[i]` names the spec serving original
    /// request `i` (identity when nothing fused). Admission pre-checks
    /// run against the FUSED footprints — the batch is what admission
    /// actually holds in flight.
    pub fn run_specs_grouped(
        &self,
        requests: &[QueryRequest],
        group_of: &[usize],
        fused: &[QueryRequest],
        specs: &[QuerySpec],
        policy: Policy,
    ) -> anyhow::Result<RunReport> {
        self.run_specs_grouped_traced(requests, group_of, fused, specs, policy, &mut NullSink)
    }

    /// [`Coordinator::run_specs_grouped`] with a [`TraceSink`] receiving
    /// every engine scheduling event (DESIGN.md §Observability). The
    /// default path above passes [`NullSink`], which monomorphizes all
    /// emission sites away — tracing is observation only and the traced
    /// report is bit-identical to the untraced one (pinned by property
    /// test).
    pub fn run_specs_grouped_traced<S: TraceSink>(
        &self,
        requests: &[QueryRequest],
        group_of: &[usize],
        fused: &[QueryRequest],
        specs: &[QuerySpec],
        policy: Policy,
        sink: &mut S,
    ) -> anyhow::Result<RunReport> {
        assert_eq!(fused.len(), specs.len());
        assert_eq!(requests.len(), group_of.len());
        let flow = match policy {
            Policy::Sequential => self.sim.run_sequential_traced(specs, sink),
            Policy::Concurrent => {
                let demand = self.ctx_demand_bytes(fused);
                let cap = self.ctx_capacity_bytes();
                anyhow::ensure!(
                    demand <= cap,
                    "{} concurrent queries reserve {} MiB and exhaust thread-context \
                     memory (capacity {} MiB, ~{} default-footprint queries; the paper \
                     hit this wall at 256 queries on 8 nodes — use ConcurrentAdmitted \
                     to degrade gracefully)",
                    specs.len(),
                    demand >> 20,
                    cap >> 20,
                    self.capacity()
                );
                self.sim.run_traced(specs, sink)
            }
            Policy::ConcurrentAdmitted { on_full, weights, preempt } => {
                weights.validate()?;
                let ledger = self.ledger();
                // A query whose declared footprint exceeds the whole
                // machine could never run — that is a workload/machine
                // configuration error, not load, so the run fails loudly
                // with the typed error instead of silently admitting it
                // (the real Pathfinder would crash) or silently dropping
                // every instance of that analysis. Callers driving the
                // engine directly get per-query degradation instead
                // (`FlowSim::run_admitted` records such queries as
                // rejections).
                for spec in specs {
                    ledger.check_admissible(spec.ctx_bytes)?;
                }
                let mut adm = ledger.policy(on_full).with_weights(weights);
                adm.preempt = preempt;
                self.sim.run_admitted_traced(specs, adm, sink)
            }
        };
        Ok(RunReport::from_flow_grouped(
            policy.label(self.ctx_capacity_bytes()),
            &self.machine,
            requests,
            group_of,
            &flow,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::{Analysis, Cc, QueryOutput};
    use crate::config::machine::MachineConfig;
    use crate::config::workload::{GraphConfig, MixPoint};
    use crate::coordinator::planner;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::rmat::Rmat;

    fn rmat(scale: u32) -> Csr {
        let r = Rmat::new(GraphConfig::with_scale(scale));
        build_undirected_csr(1 << scale, &r.edges())
    }

    fn coord(g: &Csr) -> Coordinator<'_> {
        Coordinator::new(g, Machine::new(MachineConfig::pathfinder_8()))
    }

    #[test]
    fn concurrent_beats_sequential() {
        let g = rmat(11);
        let c = coord(&g);
        let qs = planner::bfs_queries(&g, 16, 42);
        let conc = c.run(&qs, Policy::Concurrent).unwrap();
        let seq = c.run(&qs, Policy::Sequential).unwrap();
        assert!(conc.makespan_s < seq.makespan_s);
        assert!(conc.mean_channel_utilization > seq.mean_channel_utilization);
        assert_eq!(conc.completed(), 16);
    }

    #[test]
    fn concurrent_over_capacity_errors_like_the_paper() {
        let g = rmat(8);
        let mut cfg = MachineConfig::pathfinder_8();
        cfg.ctx_mem_per_node_bytes = 16 << 20; // capacity: 8 queries
        let c = Coordinator::new(&g, Machine::new(cfg));
        assert_eq!(c.capacity(), 8);
        let qs = planner::bfs_queries(&g, 9, 1);
        let err = c.run(&qs, Policy::Concurrent).unwrap_err();
        assert!(err.to_string().contains("thread-context memory"));
        // Admission control degrades gracefully instead.
        let rep = c.run(&qs, Policy::admitted(OnFull::Queue)).unwrap();
        assert_eq!(rep.completed(), 9);
        assert!(rep.peak_concurrency <= 8);
    }

    #[test]
    fn reject_policy_reports_rejections() {
        let g = rmat(8);
        let mut cfg = MachineConfig::pathfinder_8();
        cfg.ctx_mem_per_node_bytes = 16 << 20;
        let c = Coordinator::new(&g, Machine::new(cfg));
        let qs = planner::bfs_queries(&g, 10, 1);
        let rep = c.run(&qs, Policy::admitted(OnFull::Reject)).unwrap();
        assert_eq!(rep.rejections(), 2);
        assert_eq!(rep.completed(), 8);
    }

    #[test]
    fn demand_cache_hits_for_repeat_cacheable_instances() {
        let g = rmat(9);
        let c = coord(&g);
        let qs: Vec<QueryRequest> =
            (0..3).map(|_| QueryRequest::new(Cc)).collect();
        let specs = c.prepare(c.view(), 0, &qs, 0);
        // All three share phase counts; channels rotated per instance.
        assert_eq!(specs[0].phases.len(), specs[1].phases.len());
        assert_eq!(
            specs[1].phases[0].per_channel_ops,
            specs[0].phases[0].rotate_channels(1).per_channel_ops
        );
        // Node totals identical (rotation is within-node).
        assert_eq!(specs[0].phases[0].channel_ops, specs[2].phases[0].channel_ops);
        // Exactly one cache entry was populated.
        assert_eq!(c.demand_cache.borrow().len(), 1);
    }

    /// The demand-cache contract: for every cacheable analysis, a cached
    /// instance (offset-0 demand rotated k channels) must be
    /// indistinguishable from preparing that instance directly at offset
    /// k — otherwise the epoch-0 cache path and the mutation-lane direct
    /// path (epoch >= 1 bypasses the cache) would model different
    /// channel placements for identical queries.
    #[test]
    fn cacheable_demand_rotation_matches_direct_preparation() {
        use crate::alg::AnalysisRegistry;

        let g = rmat(8);
        let c = coord(&g);
        let registry = AnalysisRegistry::builtin();
        let mut covered = 0;
        for label in registry.labels() {
            let a = registry.build(label, 3).unwrap();
            if a.cacheable_demand().is_none() {
                continue;
            }
            covered += 1;
            let base = a.phases(c.view(), c.machine(), 0);
            for k in [1usize, 5] {
                let direct = a.phases(c.view(), c.machine(), k);
                let rotated: Vec<_> = base.iter().map(|p| p.rotate_channels(k)).collect();
                assert_eq!(direct, rotated, "{label} offset {k}");
            }
        }
        assert_eq!(covered, 3, "cc, pagerank and tricount are cacheable");
    }

    #[test]
    fn mixed_run_completes_and_validates_composition() {
        let g = rmat(10);
        let c = coord(&g);
        let qs = planner::mix_queries(&g, MixPoint { bfs: 12, cc: 3 }, 5);
        let rep = c.run(&qs, Policy::Concurrent).unwrap();
        assert_eq!(rep.latencies(Some("bfs")).len(), 12);
        assert_eq!(rep.latencies(Some("cc")).len(), 3);
        // CC touches every vertex; it should be slower than a BFS.
        let bfs_mean = crate::util::stats::mean(&rep.latencies(Some("bfs")));
        let cc_mean = crate::util::stats::mean(&rep.latencies(Some("cc")));
        assert!(cc_mean > bfs_mean);
    }

    #[test]
    fn arrivals_flow_through_prepare() {
        let g = rmat(8);
        let c = coord(&g);
        let mut qs = planner::bfs_queries(&g, 3, 2);
        planner::assign_arrivals(&mut qs, &[0.0, 1e9, 2e9]);
        let specs = c.prepare(c.view(), 0, &qs, 0);
        assert_eq!(specs[2].arrival_ns, 2e9);
    }

    /// The batching-aware submission path: compatible same-arrival BFS
    /// fuse into one engine query, the report fans back out to one record
    /// per member, and the fused run beats the unbatched one.
    #[test]
    fn submit_batched_fuses_and_fans_out() {
        let g = rmat(10);
        let c = coord(&g);
        let qs = planner::bfs_queries(&g, 8, 42);
        let batch = BatchConfig { width: 8, window_ns: 1e9 };
        let rep = c.submit_batched(qs.clone(), Policy::admitted(OnFull::Queue), &batch).unwrap();
        assert_eq!(rep.records.len(), 8, "one record per MEMBER");
        assert_eq!(rep.completed(), 8);
        assert!(rep.records.iter().all(|r| r.label == "bfs"), "member labels survive fusion");
        // All members rode one engine query: identical finish instants.
        let f0 = rep.records[0].finish_s;
        assert!(rep.records.iter().all(|r| r.finish_s == f0));
        let unbatched = c.run(&qs, Policy::admitted(OnFull::Queue)).unwrap();
        let fused_mean = rep.mean_latency_s().expect("all members completed");
        let unbatched_mean = unbatched.mean_latency_s().expect("all queries completed");
        assert!(
            fused_mean < unbatched_mean,
            "fused {fused_mean} vs unbatched {unbatched_mean}"
        );
        // Width 1 degenerates to the plain submission path exactly.
        let solo_cfg = BatchConfig { width: 1, window_ns: 1e9 };
        let solo = c.submit_batched(qs.clone(), Policy::admitted(OnFull::Queue), &solo_cfg).unwrap();
        assert_eq!(solo.mean_latency_s(), unbatched.mean_latency_s());
        assert_eq!(solo.makespan_s, unbatched.makespan_s);
    }

    /// The traced path is observation only: same report, plus a
    /// non-empty event stream covering the query lifecycle.
    #[test]
    fn traced_run_matches_untraced_and_emits_lifecycle() {
        let g = rmat(9);
        let c = coord(&g);
        let qs = planner::bfs_queries(&g, 6, 7);
        let specs = c.prepare(c.view(), 0, &qs, 0);
        let identity: Vec<usize> = (0..qs.len()).collect();
        let mut buf = crate::sim::trace::TraceBuffer::new();
        let policy = Policy::admitted(OnFull::Queue);
        let traced = c
            .run_specs_grouped_traced(&qs, &identity, &qs, &specs, policy, &mut buf)
            .unwrap();
        let plain = c.run_specs(&qs, &specs, policy).unwrap();
        assert_eq!(traced.completed(), plain.completed());
        assert_eq!(traced.makespan_s, plain.makespan_s);
        let kinds: Vec<&str> = buf.counts_by_kind().iter().map(|&(k, _)| k).collect();
        for kind in ["arrival", "admit", "phase_start", "phase_end", "finish", "solve"] {
            assert!(kinds.contains(&kind), "missing {kind} in {kinds:?}");
        }
    }

    #[test]
    fn submit_is_the_owned_run_path() {
        let g = rmat(9);
        let c = coord(&g);
        let qs = planner::bfs_queries(&g, 4, 3);
        let rep = c.submit(qs, Policy::Sequential).unwrap();
        assert_eq!(rep.completed(), 4);
    }

    /// A deliberately context-hungry analysis shrinks effective capacity:
    /// the declared footprint, not the query count, is what admission sums.
    #[derive(Debug)]
    struct FatCc;

    impl Analysis for FatCc {
        fn label(&self) -> &'static str {
            "fat-cc"
        }
        fn run_offset(&self, g: GraphView<'_>, m: &Machine, o: usize) -> QueryOutput {
            let run = crate::alg::cc_run_offset(g, m, o);
            QueryOutput { label: self.label(), values: run.labels, phases: run.phases }
        }
        fn validate(&self, g: GraphView<'_>, values: &[i64]) -> anyhow::Result<()> {
            crate::alg::oracle::check_cc(g, values)
        }
        fn ctx_mem_bytes(&self, _g: GraphView<'_>, _m: &Machine) -> Option<u64> {
            Some(1 << 30) // 1 GiB per instance
        }
    }

    #[test]
    fn declared_ctx_footprint_drives_concurrent_admission() {
        let g = rmat(8);
        let mut cfg = MachineConfig::pathfinder_8();
        cfg.ctx_mem_per_node_bytes = 256 << 20; // 2 GiB total => 128 default queries
        let c = Coordinator::new(&g, Machine::new(cfg));
        assert_eq!(c.capacity(), 128);
        // Two fat queries fit (2 GiB), three do not — long before the
        // 128-query default count.
        let two: Vec<QueryRequest> = (0..2).map(|_| QueryRequest::new(FatCc)).collect();
        assert!(c.run(&two, Policy::Concurrent).is_ok());
        let three: Vec<QueryRequest> = (0..3).map(|_| QueryRequest::new(FatCc)).collect();
        let err = c.run(&three, Policy::Concurrent).unwrap_err();
        assert!(err.to_string().contains("thread-context memory"));
    }

    #[test]
    fn declared_ctx_footprint_bounds_admitted_concurrency() {
        let g = rmat(8);
        let mut cfg = MachineConfig::pathfinder_8();
        cfg.ctx_mem_per_node_bytes = 256 << 20; // 2 GiB total
        let c = Coordinator::new(&g, Machine::new(cfg));
        // Admission must hold at most 2 GiB / 1 GiB = 2 fat queries in
        // flight — not the 128 a default-footprint count would allow.
        let fat: Vec<QueryRequest> = (0..5).map(|_| QueryRequest::new(FatCc)).collect();
        let rep = c.run(&fat, Policy::admitted(OnFull::Queue)).unwrap();
        assert_eq!(rep.completed(), 5);
        assert!(rep.peak_concurrency <= 2, "peak {}", rep.peak_concurrency);
    }

    /// Byte accounting is exact, not divide-by-fattest: one fat query
    /// must not shrink the machine for a stream of thin ones.
    #[test]
    fn byte_ledger_admits_thin_queries_alongside_fat() {
        let g = rmat(8);
        let mut cfg = MachineConfig::pathfinder_8();
        cfg.ctx_mem_per_node_bytes = 256 << 20; // 2 GiB total
        let c = Coordinator::new(&g, Machine::new(cfg));
        // 1 fat (1 GiB) + 8 thin (16 MiB each) = 1.125 GiB: everything
        // fits concurrently. The old fattest-footprint heuristic capped
        // in-flight work at 2 queries.
        let mut batch: Vec<QueryRequest> = vec![QueryRequest::new(FatCc)];
        batch.extend(planner::bfs_queries(&g, 8, 1));
        let rep = c.run(&batch, Policy::admitted(OnFull::Queue)).unwrap();
        assert_eq!(rep.completed(), 9);
        assert!(
            rep.peak_concurrency > 2,
            "exact byte accounting must beat the divide-by-fattest cap, peak {}",
            rep.peak_concurrency
        );
    }

    /// A lone query whose declared footprint exceeds the whole machine is
    /// refused with the typed `ContextExhausted` error — it is not
    /// silently admitted to a run that would crash the real Pathfinder.
    #[test]
    fn oversized_query_yields_typed_context_exhausted() {
        use crate::coordinator::admission::ContextExhausted;

        let g = rmat(8);
        let mut cfg = MachineConfig::pathfinder_8();
        cfg.ctx_mem_per_node_bytes = 64 << 20; // 512 MiB total < 1 GiB
        let c = Coordinator::new(&g, Machine::new(cfg));
        let one: Vec<QueryRequest> = vec![QueryRequest::new(FatCc)];
        for on_full in [OnFull::Queue, OnFull::Reject, OnFull::Shed { max_waiting: 4 }] {
            let err = c.run(&one, Policy::admitted(on_full)).unwrap_err();
            let ctx = err
                .downcast_ref::<ContextExhausted>()
                .unwrap_or_else(|| panic!("want typed ContextExhausted, got {err:#}"));
            assert!(ctx.oversized());
            assert_eq!(ctx.requested_bytes, 1 << 30);
            assert_eq!(ctx.capacity_bytes, 512 << 20);
        }
    }

    #[test]
    fn policy_labels_carry_the_effective_cap() {
        let g = rmat(8);
        let mut cfg = MachineConfig::pathfinder_8();
        cfg.ctx_mem_per_node_bytes = 16 << 20; // 128 MiB total
        let c = Coordinator::new(&g, Machine::new(cfg));
        let qs = planner::bfs_queries(&g, 2, 1);
        let rep = c.run(&qs, Policy::admitted(OnFull::Queue)).unwrap();
        assert_eq!(rep.policy, "concurrent(queue, cap=128MiB)");
        let seq = c.run(&qs, Policy::Sequential).unwrap();
        assert_eq!(seq.policy, "sequential");
    }

    /// Non-flat weights and preemption are visible in the policy label, so
    /// runs under different sharing policies never collide in a report.
    #[test]
    fn weighted_preempt_policy_labeled_and_runs() {
        use crate::sim::preempt::PreemptPolicy;

        let g = rmat(9);
        let mut cfg = MachineConfig::pathfinder_8();
        cfg.ctx_mem_per_node_bytes = 16 << 20; // 128 MiB total
        let c = Coordinator::new(&g, Machine::new(cfg));
        let mut qs = planner::bfs_queries(&g, 12, 1);
        planner::assign_round_robin_priorities(&mut qs);
        let policy = Policy::ConcurrentAdmitted {
            on_full: OnFull::Queue,
            weights: ShareWeights::priority_weighted(),
            preempt: Some(PreemptPolicy::default()),
        };
        let rep = c.run(&qs, policy).unwrap();
        assert_eq!(rep.policy, "concurrent(queue, cap=128MiB, w=4:2:1)+preempt");
        assert_eq!(rep.completed(), 12);
        // Invalid weights are refused before the engine runs.
        let bad = Policy::ConcurrentAdmitted {
            on_full: OnFull::Queue,
            weights: ShareWeights { interactive: 0.0, standard: 1.0, batch: 1.0 },
            preempt: None,
        };
        assert!(c.run(&qs, bad).is_err());
    }
}
