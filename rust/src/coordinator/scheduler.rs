//! The coordinator proper: execute a query list under a policy.
//!
//! Owns the machine, the flow engine, and the demand cache. Responsible for
//! the stripe-offset assignment (each concurrent query's own arrays land on
//! rotated channels — see [`crate::alg::bfs::bfs_run_offset`]) and for the
//! connected-components demand cache: CC has no per-query parameter, so its
//! (expensive) functional execution runs once and each further instance is
//! a cheap channel rotation of the cached phases.

use crate::alg::Query;
use crate::graph::csr::Csr;
use crate::sim::demand::PhaseDemand;
use crate::sim::flow::{Admission, FlowSim, OnFull, QuerySpec};
use crate::sim::machine::Machine;

use super::metrics::RunReport;

/// Execution policy for a batch of queries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// One query at a time, in submission order (the paper's baseline arm).
    Sequential,
    /// All queries at once, no admission control — the paper's concurrent
    /// arm ("without any explicit scheduling or allocation of resources").
    /// Exceeding the machine's thread-context memory is *fatal* on the real
    /// Pathfinder; here `run` returns an error instead.
    Concurrent,
    /// Concurrent with admission control at the machine's context capacity:
    /// the overload behavior a production deployment would choose.
    ConcurrentAdmitted { on_full: OnFull },
}

impl Policy {
    pub fn label(&self) -> String {
        match self {
            Policy::Sequential => "sequential".into(),
            Policy::Concurrent => "concurrent".into(),
            Policy::ConcurrentAdmitted { on_full: OnFull::Queue } => "concurrent(queue)".into(),
            Policy::ConcurrentAdmitted { on_full: OnFull::Reject } => {
                "concurrent(reject)".into()
            }
        }
    }
}

/// The concurrent-query coordinator for one graph on one machine.
pub struct Coordinator<'g> {
    g: &'g Csr,
    machine: Machine,
    sim: FlowSim,
    /// Cached CC demand at stripe offset 0 (computed on first use).
    cc_cache: std::cell::RefCell<Option<Vec<PhaseDemand>>>,
}

impl<'g> Coordinator<'g> {
    pub fn new(g: &'g Csr, machine: Machine) -> Self {
        let sim = FlowSim::new(machine.clone());
        Coordinator { g, machine, sim, cc_cache: std::cell::RefCell::new(None) }
    }

    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    pub fn graph(&self) -> &Csr {
        self.g
    }

    /// Thread-context capacity of this machine (queries).
    pub fn capacity(&self) -> usize {
        self.machine.cfg.max_concurrent_queries()
    }

    /// Build engine-ready specs for a query list: functional execution +
    /// demand emission, stripe offset = position in the batch, arrival 0.
    pub fn prepare(&self, queries: &[Query]) -> Vec<QuerySpec> {
        self.prepare_with_arrivals(queries, None)
    }

    /// `prepare` with explicit arrival times (ns); `None` = all at 0.
    pub fn prepare_with_arrivals(
        &self,
        queries: &[Query],
        arrivals: Option<&[f64]>,
    ) -> Vec<QuerySpec> {
        if let Some(a) = arrivals {
            assert_eq!(a.len(), queries.len(), "one arrival per query");
        }
        queries
            .iter()
            .enumerate()
            .map(|(i, q)| {
                let phases = match q {
                    Query::Bfs { .. } => q.phases(self.g, &self.machine, i),
                    Query::Cc => {
                        // Source-free: compute once, rotate per instance.
                        let mut cache = self.cc_cache.borrow_mut();
                        let base = cache.get_or_insert_with(|| {
                            Query::Cc.phases(self.g, &self.machine, 0)
                        });
                        base.iter().map(|p| p.rotate_channels(i)).collect()
                    }
                };
                QuerySpec {
                    id: i,
                    label: q.label(),
                    phases,
                    arrival_ns: arrivals.map(|a| a[i]).unwrap_or(0.0),
                }
            })
            .collect()
    }

    /// Execute `queries` under `policy` and report.
    pub fn run(&self, queries: &[Query], policy: Policy) -> anyhow::Result<RunReport> {
        let specs = self.prepare(queries);
        self.run_specs(queries, &specs, policy)
    }

    /// Execute pre-prepared specs (lets the bench harness prepare once and
    /// run many sample points).
    pub fn run_specs(
        &self,
        queries: &[Query],
        specs: &[QuerySpec],
        policy: Policy,
    ) -> anyhow::Result<RunReport> {
        let flow = match policy {
            Policy::Sequential => self.sim.run_sequential(specs),
            Policy::Concurrent => {
                anyhow::ensure!(
                    specs.len() <= self.capacity(),
                    "{} concurrent queries exhaust thread-context memory \
                     (capacity {}; the paper hit this wall at 256 queries \
                     on 8 nodes — use ConcurrentAdmitted to degrade \
                     gracefully)",
                    specs.len(),
                    self.capacity()
                );
                self.sim.run(specs)
            }
            Policy::ConcurrentAdmitted { on_full } => {
                let adm = Admission { max_in_flight: Some(self.capacity()), on_full };
                self.sim.run_admitted(specs, adm)
            }
        };
        Ok(RunReport::from_flow(policy.label(), &self.machine, queries, &flow))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::{GraphConfig, MixPoint};
    use crate::coordinator::planner;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::rmat::Rmat;

    fn rmat(scale: u32) -> Csr {
        let r = Rmat::new(GraphConfig::with_scale(scale));
        build_undirected_csr(1 << scale, &r.edges())
    }

    fn coord(g: &Csr) -> Coordinator<'_> {
        Coordinator::new(g, Machine::new(MachineConfig::pathfinder_8()))
    }

    #[test]
    fn concurrent_beats_sequential() {
        let g = rmat(11);
        let c = coord(&g);
        let qs = planner::bfs_queries(&g, 16, 42);
        let conc = c.run(&qs, Policy::Concurrent).unwrap();
        let seq = c.run(&qs, Policy::Sequential).unwrap();
        assert!(conc.makespan_s < seq.makespan_s);
        assert!(conc.mean_channel_utilization > seq.mean_channel_utilization);
        assert_eq!(conc.completed(), 16);
    }

    #[test]
    fn concurrent_over_capacity_errors_like_the_paper() {
        let g = rmat(8);
        let mut cfg = MachineConfig::pathfinder_8();
        cfg.ctx_mem_per_node_bytes = 16 << 20; // capacity: 8 queries
        let c = Coordinator::new(&g, Machine::new(cfg));
        assert_eq!(c.capacity(), 8);
        let qs = planner::bfs_queries(&g, 9, 1);
        let err = c.run(&qs, Policy::Concurrent).unwrap_err();
        assert!(err.to_string().contains("thread-context memory"));
        // Admission control degrades gracefully instead.
        let rep = c
            .run(&qs, Policy::ConcurrentAdmitted { on_full: OnFull::Queue })
            .unwrap();
        assert_eq!(rep.completed(), 9);
        assert!(rep.peak_concurrency <= 8);
    }

    #[test]
    fn reject_policy_reports_rejections() {
        let g = rmat(8);
        let mut cfg = MachineConfig::pathfinder_8();
        cfg.ctx_mem_per_node_bytes = 16 << 20;
        let c = Coordinator::new(&g, Machine::new(cfg));
        let qs = planner::bfs_queries(&g, 10, 1);
        let rep = c
            .run(&qs, Policy::ConcurrentAdmitted { on_full: OnFull::Reject })
            .unwrap();
        assert_eq!(rep.rejections(), 2);
        assert_eq!(rep.completed(), 8);
    }

    #[test]
    fn cc_cache_hits_for_repeat_instances() {
        let g = rmat(9);
        let c = coord(&g);
        let qs = vec![Query::Cc, Query::Cc, Query::Cc];
        let specs = c.prepare(&qs);
        // All three share phase counts; channels rotated per instance.
        assert_eq!(specs[0].phases.len(), specs[1].phases.len());
        assert_eq!(
            specs[1].phases[0].per_channel_ops,
            specs[0].phases[0].rotate_channels(1).per_channel_ops
        );
        // Node totals identical (rotation is within-node).
        assert_eq!(specs[0].phases[0].channel_ops, specs[2].phases[0].channel_ops);
    }

    #[test]
    fn mixed_run_completes_and_validates_composition() {
        let g = rmat(10);
        let c = coord(&g);
        let qs = planner::mix_queries(&g, MixPoint { bfs: 12, cc: 3 }, 5);
        let rep = c.run(&qs, Policy::Concurrent).unwrap();
        assert_eq!(rep.latencies(Some("bfs")).len(), 12);
        assert_eq!(rep.latencies(Some("cc")).len(), 3);
        // CC touches every vertex; it should be slower than a BFS.
        let bfs_mean = crate::util::stats::mean(&rep.latencies(Some("bfs")));
        let cc_mean = crate::util::stats::mean(&rep.latencies(Some("cc")));
        assert!(cc_mean > bfs_mean);
    }

    #[test]
    fn arrivals_flow_through_prepare() {
        let g = rmat(8);
        let c = coord(&g);
        let qs = planner::bfs_queries(&g, 3, 2);
        let arr = vec![0.0, 1e9, 2e9];
        let specs = c.prepare_with_arrivals(&qs, Some(&arr));
        assert_eq!(specs[2].arrival_ns, 2e9);
    }
}
