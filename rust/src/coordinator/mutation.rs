//! The mutation lane: streaming edge ingest served *alongside* queries
//! (DESIGN.md §Mutation).
//!
//! `serve --mutate rate=R,batch=B` adds a Poisson stream of update batches
//! to the service timeline. Each batch:
//!
//! 1. is generated reproducibly from the service seed's forked mutation
//!    stream ([`crate::graph::delta::random_batch`]);
//! 2. advances the [`crate::graph::store::GraphStore`] to a new epoch
//!    (queries pin the epoch current at their admission);
//! 3. becomes an [`IngestBatch`] request — a real [`Analysis`] labeled
//!    `"mutate"` whose demand is the memory-side ingest model
//!    ([`crate::sim::demand::PhaseDemand::ingest_batch`]) — submitted as
//!    **Batch-class** work, so the existing ledger/weights/preemption
//!    machinery admits, shares, parks and reports it like any other work.
//!
//! After the engine runs, the service replays completions against the
//! store (unpinning each query's epoch at its finish time) and compacts
//! whenever the drained overlay prefix reaches
//! [`MutationConfig::compact_every`] — compaction never retires a pinned
//! epoch, which the snapshot-isolation property tests pin down.
//!
//! [`IngestBatch`] is also the degenerate example of the open query API
//! (docs/ANALYSES.md): an [`Analysis`] with no per-vertex values and no
//! oracle of its own (the store's snapshot-isolation properties validate
//! the *data*; the analysis only carries the ingest *bandwidth* model),
//! which is exactly enough for the ledger, weights, preemption and
//! per-class reporting to treat mutation like any other workload class.

use crate::alg::analysis::{Analysis, QueryOutput};
use crate::graph::delta::EdgeUpdate;
use crate::graph::view::GraphView;
use crate::sim::demand::PhaseDemand;
use crate::sim::machine::Machine;
use crate::util::stats::Quantiles;
use std::sync::Arc;

/// Configuration of the `serve --mutate` ingest lane.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MutationConfig {
    /// Mean update-batch arrival rate (batches/s of simulated time).
    pub rate_batches_per_s: f64,
    /// Updates per batch.
    pub batch: usize,
    /// Fraction of updates that delete a currently-present edge (the rest
    /// insert random pairs).
    pub delete_fraction: f64,
    /// Compact once this many overlays are drained of pins.
    pub compact_every: usize,
}

impl Default for MutationConfig {
    fn default() -> Self {
        MutationConfig {
            rate_batches_per_s: 50.0,
            batch: 64,
            delete_fraction: 0.1,
            compact_every: 4,
        }
    }
}

impl MutationConfig {
    /// Parse `rate=R,batch=B[,delete=F][,compact=K]` (the CLI
    /// `serve --mutate` argument). Omitted keys keep defaults.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut cfg = MutationConfig::default();
        for (key, value) in crate::util::cli::parse_kv_f64_list(spec, "mutation spec")? {
            match key {
                "rate" => cfg.rate_batches_per_s = value,
                "batch" => cfg.batch = value as usize,
                "delete" => cfg.delete_fraction = value,
                "compact" => cfg.compact_every = value as usize,
                other => anyhow::bail!(
                    "unknown mutation key {other:?} (want rate/batch/delete/compact)"
                ),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.rate_batches_per_s.is_finite() && self.rate_batches_per_s > 0.0,
            "mutation rate must be positive, got {}",
            self.rate_batches_per_s
        );
        anyhow::ensure!(self.batch >= 1, "mutation batch size must be at least 1");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.delete_fraction),
            "delete fraction must be in [0, 1], got {}",
            self.delete_fraction
        );
        anyhow::ensure!(self.compact_every >= 1, "compact threshold must be at least 1");
        Ok(())
    }

    /// Compact `rate=..,batch=..` description for report headers.
    pub fn label(&self) -> String {
        format!(
            "rate={},batch={},delete={},compact={}",
            self.rate_batches_per_s, self.batch, self.delete_fraction, self.compact_every
        )
    }
}

/// One applied update batch as a schedulable [`Analysis`]: label
/// `"mutate"`, no result values (nothing for an oracle to check — the
/// snapshot-isolation tests validate the *store* instead), demand = the
/// memory-side ingest model. Prepared like any query, admitted as
/// Batch-class work, visible per class in every report.
#[derive(Debug)]
pub struct IngestBatch {
    updates: Arc<Vec<EdgeUpdate>>,
    /// Epoch this batch created in the store (for `describe`).
    epoch: u64,
}

impl IngestBatch {
    pub fn new(updates: Arc<Vec<EdgeUpdate>>, epoch: u64) -> Self {
        IngestBatch { updates, epoch }
    }

    pub fn updates(&self) -> &[EdgeUpdate] {
        &self.updates
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

/// The class label every ingest batch reports under.
pub const MUTATE_LABEL: &str = "mutate";

impl Analysis for IngestBatch {
    fn label(&self) -> &'static str {
        MUTATE_LABEL
    }

    fn describe(&self) -> String {
        format!("mutate(batch={},epoch={})", self.updates.len(), self.epoch)
    }

    fn run_offset(&self, g: GraphView<'_>, m: &Machine, stripe_offset: usize) -> QueryOutput {
        // Demand depends on endpoints + layout, not edge blocks; the
        // stripe offset is ignored because the delta log is shared graph
        // state at a fixed home channel, not a per-query private array.
        let _ = (g, stripe_offset);
        QueryOutput {
            label: self.label(),
            values: Vec::new(),
            phases: vec![PhaseDemand::ingest_batch(m, &self.updates)],
        }
    }

    fn validate(&self, _g: GraphView<'_>, values: &[i64]) -> anyhow::Result<()> {
        anyhow::ensure!(values.is_empty(), "ingest batches produce no per-vertex values");
        Ok(())
    }
}

/// The class label every compaction fold reports under.
pub const COMPACT_LABEL: &str = "compact";

/// One compaction pass as a schedulable [`Analysis`]: label `"compact"`,
/// no result values, demand = the merge-traffic model
/// ([`PhaseDemand::compaction_fold`]). Submitted as **Batch-class** work
/// by `serve --mutate` at the simulated time the store compacts, so
/// folding drained overlays back into a flat base competes for stream and
/// channel bandwidth with live queries instead of being free.
#[derive(Debug)]
pub struct CompactionFold {
    /// Vertices in the base being rebuilt.
    n: usize,
    /// Directed arcs in the old base CSR (streamed out and back).
    base_arcs: usize,
    /// Directed arc records in the drained overlays being folded.
    drained_arcs: usize,
    /// Epoch the rebuilt base lands on (for `describe`).
    base_epoch: u64,
}

impl CompactionFold {
    pub fn new(n: usize, base_arcs: usize, drained_arcs: usize, base_epoch: u64) -> Self {
        CompactionFold { n, base_arcs, drained_arcs, base_epoch }
    }
}

impl Analysis for CompactionFold {
    fn label(&self) -> &'static str {
        COMPACT_LABEL
    }

    fn describe(&self) -> String {
        format!(
            "compact(base_arcs={},drained={},epoch={})",
            self.base_arcs, self.drained_arcs, self.base_epoch
        )
    }

    fn run_offset(&self, g: GraphView<'_>, m: &Machine, stripe_offset: usize) -> QueryOutput {
        // Like ingest, the fold works on shared graph state (the base CSR
        // and the delta logs), striped at fixed homes: no stripe offset.
        let _ = (g, stripe_offset);
        QueryOutput {
            label: self.label(),
            values: Vec::new(),
            phases: vec![PhaseDemand::compaction_fold(m, self.n, self.base_arcs, self.drained_arcs)],
        }
    }

    fn validate(&self, _g: GraphView<'_>, values: &[i64]) -> anyhow::Result<()> {
        anyhow::ensure!(values.is_empty(), "compaction folds produce no per-vertex values");
        Ok(())
    }
}

/// Mutation-lane section of a [`crate::coordinator::ServiceReport`].
#[derive(Debug, Clone)]
pub struct MutationStats {
    /// Seed of the forked mutation stream (regenerate with
    /// `serve --mutate ... --seed S`: the stream is derived from the
    /// service seed, so one number reproduces the whole run).
    pub seed: u64,
    /// Update batches applied (== epochs created).
    pub batches: usize,
    /// Update records submitted across all batches.
    pub updates: usize,
    /// Undirected edges actually inserted (absent before their batch).
    pub inserted: usize,
    /// Undirected edges actually deleted.
    pub deleted: usize,
    /// No-op updates (insert-present / delete-absent / cancelled in
    /// batch).
    pub redundant: usize,
    /// Compaction passes run during the replay.
    pub compactions: usize,
    /// Overlays folded into the base across all passes.
    pub overlays_compacted: usize,
    /// Overlays still live at the end of the run (pinned tail).
    pub final_overlays: usize,
    /// Applied updates per second of service duration.
    pub update_throughput_per_s: f64,
    /// Latency quantiles of completed ingest batches (s), if any.
    pub batch_latency: Option<Quantiles>,
}

impl MutationStats {
    /// One operator-facing summary line.
    pub fn line(&self) -> String {
        format!(
            "mutation: {} batches / {} updates ({} ins, {} del, {} no-op) — \
             {:.0} upd/s, {} epochs, {} compactions ({} overlays folded, {} live), \
             seed {:#x}",
            self.batches,
            self.updates,
            self.inserted,
            self.deleted,
            self.redundant,
            self.update_throughput_per_s,
            self.batches,
            self.compactions,
            self.overlays_compacted,
            self.final_overlays,
            self.seed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::graph::builder::build_undirected_csr;

    #[test]
    fn parse_and_validate() {
        let c = MutationConfig::parse("rate=200, batch=32, delete=0.25, compact=2").unwrap();
        assert_eq!(c.rate_batches_per_s, 200.0);
        assert_eq!(c.batch, 32);
        assert_eq!(c.delete_fraction, 0.25);
        assert_eq!(c.compact_every, 2);
        // Defaults survive partial specs.
        let c = MutationConfig::parse("rate=10").unwrap();
        assert_eq!(c.batch, MutationConfig::default().batch);
        assert!(MutationConfig::parse("rate=0").is_err());
        assert!(MutationConfig::parse("batch=0").is_err());
        assert!(MutationConfig::parse("delete=1.5").is_err());
        // Pure-delete streams are supported (the delete-heavy follow-up).
        assert!(MutationConfig::parse("delete=1.0").is_ok());
        assert!(MutationConfig::parse("tempo=3").is_err());
        assert!(!c.label().is_empty());
    }

    #[test]
    fn compaction_fold_is_a_well_formed_batch_analysis() {
        let g = build_undirected_csr(16, &[(0, 1), (2, 3)]);
        let m = Machine::new(MachineConfig::pathfinder_8());
        let a = CompactionFold::new(16, 4, 6, 2);
        assert_eq!(a.label(), COMPACT_LABEL);
        assert_eq!(a.describe(), "compact(base_arcs=4,drained=6,epoch=2)");
        let out = a.run(g.view(), &m);
        assert!(out.values.is_empty());
        assert_eq!(out.phases, vec![PhaseDemand::compaction_fold(&m, 16, 4, 6)]);
        a.validate(g.view(), &out.values).unwrap();
        assert!(a.validate(g.view(), &[1]).is_err());
        assert!(a.cacheable_demand().is_none());
        assert!(a.source_vertex().is_none());
    }

    #[test]
    fn ingest_batch_is_a_well_formed_analysis() {
        let g = build_undirected_csr(16, &[(0, 1), (2, 3)]);
        let m = Machine::new(MachineConfig::pathfinder_8());
        let a = IngestBatch::new(
            Arc::new(vec![EdgeUpdate::insert(4, 5), EdgeUpdate::delete(0, 1)]),
            3,
        );
        assert_eq!(a.label(), MUTATE_LABEL);
        assert_eq!(a.describe(), "mutate(batch=2,epoch=3)");
        assert_eq!(a.epoch(), 3);
        assert_eq!(a.updates().len(), 2);
        let out = a.run(g.view(), &m);
        assert!(out.values.is_empty());
        assert_eq!(out.phases.len(), 1);
        assert!(out.solo_ns(&m) > 0.0);
        a.validate(g.view(), &out.values).unwrap();
        assert!(a.validate(g.view(), &[1]).is_err());
        assert!(a.cacheable_demand().is_none(), "every batch's demand is unique");
    }
}
