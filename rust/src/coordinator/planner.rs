//! Workload planning: concrete request lists from workload descriptions.

use crate::alg::{AnalysisRegistry, Bfs, Cc, KHop, PageRank, Sssp, TriCount};
use crate::config::workload::MixPoint;
use crate::coordinator::request::QueryRequest;
use crate::graph::csr::Csr;
use crate::graph::sample::bfs_sources;
use crate::util::rng::SplitMix64;

/// `k` BFS requests from unique, reproducibly pseudorandom, non-isolated
/// sources (paper §IV-A).
pub fn bfs_queries(g: &Csr, k: usize, seed: u64) -> Vec<QueryRequest> {
    bfs_sources(g, k, seed).into_iter().map(|src| QueryRequest::new(Bfs { src })).collect()
}

/// `k` delta-stepping SSSP requests from unique non-isolated sources.
pub fn sssp_queries(g: &Csr, k: usize, seed: u64) -> Vec<QueryRequest> {
    bfs_sources(g, k, seed).into_iter().map(|src| QueryRequest::new(Sssp { src })).collect()
}

/// `k` hop-bounded neighborhood requests from unique non-isolated sources.
pub fn khop_queries(g: &Csr, k: usize, hops: u32, seed: u64) -> Vec<QueryRequest> {
    bfs_sources(g, k, seed)
        .into_iter()
        .map(|src| QueryRequest::new(KHop::new(src, hops)))
        .collect()
}

/// `k` connected-components requests (source-free).
pub fn cc_queries(k: usize) -> Vec<QueryRequest> {
    (0..k).map(|_| QueryRequest::new(Cc)).collect()
}

/// `k` PageRank requests (source-free, demand-cacheable).
pub fn pagerank_queries(k: usize) -> Vec<QueryRequest> {
    (0..k).map(|_| QueryRequest::new(PageRank)).collect()
}

/// `k` triangle-counting requests (source-free, demand-cacheable).
pub fn tricount_queries(k: usize) -> Vec<QueryRequest> {
    (0..k).map(|_| QueryRequest::new(TriCount)).collect()
}

/// `k` requests of the registry analysis `label`: a sourced analysis
/// draws unique pseudorandom non-isolated sources ([`bfs_sources`]); a
/// source-free one repeats its single instance. The registry-driven
/// form of the per-analysis helpers above — `run --analysis` resolves
/// every builtin through this one function, with no per-analysis CLI
/// code.
pub fn registry_queries(
    g: &Csr,
    reg: &AnalysisRegistry,
    label: &str,
    k: usize,
    seed: u64,
) -> anyhow::Result<Vec<QueryRequest>> {
    // Probe whether the class is rooted (the source argument of a
    // source-free factory is ignored, so 0 is safe either way).
    let probe = reg.build(label, 0)?;
    if probe.source_vertex().is_some() {
        bfs_sources(g, k, seed)
            .into_iter()
            .map(|src| Ok(QueryRequest::from_arc(reg.build(label, src)?)))
            .collect()
    } else {
        Ok((0..k).map(|_| QueryRequest::from_arc(std::sync::Arc::clone(&probe))).collect())
    }
}

/// A Table-II style mix: `mix.bfs` BFS requests + `mix.cc` connected
/// components evaluations. The *submission* order interleaves them
/// round-robin-ish (a realistic mixed arrival stream); the paper's
/// sequential baseline ("all the breadth-first searches followed by all the
/// connected components evaluations", §IV-C) is produced by
/// [`sequential_mix_order`].
pub fn mix_queries(g: &Csr, mix: MixPoint, seed: u64) -> Vec<QueryRequest> {
    let bfs = bfs_queries(g, mix.bfs, seed);
    let mut out = Vec::with_capacity(mix.total());
    // Spread the CC queries evenly through the BFS stream.
    let stride = if mix.cc == 0 { usize::MAX } else { mix.total().div_ceil(mix.cc) };
    let mut bi = 0;
    let mut placed_cc = 0;
    for i in 0..mix.total() {
        if placed_cc < mix.cc && i % stride == stride - 1 {
            out.push(QueryRequest::new(Cc));
            placed_cc += 1;
        } else if bi < bfs.len() {
            out.push(bfs[bi].clone());
            bi += 1;
        } else {
            out.push(QueryRequest::new(Cc));
            placed_cc += 1;
        }
    }
    out
}

/// Interleave several per-class request lists into one mixed stream by
/// fractional progress, so each class is spread evenly across the batch
/// regardless of its share (the general form of [`mix_queries`]'s
/// two-class interleave).
pub fn interleave_classes(classes: Vec<Vec<QueryRequest>>) -> Vec<QueryRequest> {
    let total: usize = classes.iter().map(|c| c.len()).sum();
    let mut idx = vec![0usize; classes.len()];
    let mut out = Vec::with_capacity(total);
    for _ in 0..total {
        // The class furthest behind its fractional progress goes next.
        let mut best: Option<(usize, f64)> = None;
        for (c, q) in classes.iter().enumerate() {
            if idx[c] < q.len() {
                let p = (idx[c] as f64 + 1.0) / q.len() as f64;
                if best.is_none_or(|(_, bp)| p < bp) {
                    best = Some((c, p));
                }
            }
        }
        let (c, _) = best.expect("total counted non-empty classes");
        out.push(classes[c][idx[c]].clone());
        idx[c] += 1;
    }
    out
}

/// The paper's sequential ordering of a mixed stream, generalized: group
/// requests by analysis class, classes in order of first appearance (for a
/// BFS+CC mix this is exactly "all the breadth-first searches followed by
/// all the connected components evaluations", §IV-C).
pub fn sequential_mix_order(requests: &[QueryRequest]) -> Vec<QueryRequest> {
    let labels =
        crate::coordinator::request::distinct_labels(requests.iter().map(|r| r.label()));
    let mut out = Vec::with_capacity(requests.len());
    for label in labels {
        out.extend(requests.iter().filter(|r| r.label() == label).cloned());
    }
    out
}

/// Overwrite each request's arrival time in place (one arrival per
/// request).
pub fn assign_arrivals(requests: &mut [QueryRequest], arrivals: &[f64]) {
    assert_eq!(requests.len(), arrivals.len(), "one arrival per request");
    for (r, &a) in requests.iter_mut().zip(arrivals) {
        r.arrival_ns = a;
    }
}

/// Assign priority classes round-robin across the batch (an even
/// interactive/standard/batch mix — the shape the overload experiments
/// use to exercise priority-aware admission).
pub fn assign_round_robin_priorities(requests: &mut [QueryRequest]) {
    use crate::coordinator::request::Priority;
    for (i, r) in requests.iter_mut().enumerate() {
        r.priority = Priority::ALL[i % Priority::ALL.len()];
    }
}

/// Poisson arrival times: `k` arrivals at `rate_per_s`, reproducible from
/// `seed`. Returns times in ns, sorted.
pub fn arrival_times(k: usize, rate_per_s: f64, seed: u64) -> Vec<f64> {
    assert!(rate_per_s > 0.0, "arrival rate must be positive");
    let mut rng = SplitMix64::new(seed);
    let mut t = 0.0f64;
    (0..k)
        .map(|_| {
            // Inverse-CDF exponential inter-arrival; clamp u away from 0.
            let u = rng.next_f64().max(1e-12);
            t += -u.ln() / rate_per_s * 1e9;
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::rmat::Rmat;

    fn g() -> Csr {
        let r = Rmat::new(GraphConfig::with_scale(10));
        build_undirected_csr(1 << 10, &r.edges())
    }

    fn srcs_of(requests: &[QueryRequest]) -> Vec<String> {
        requests.iter().map(|r| r.to_string()).collect()
    }

    #[test]
    fn bfs_queries_unique_sources() {
        let g = g();
        let qs = bfs_queries(&g, 64, 7);
        assert!(qs.iter().all(|q| q.label() == "bfs"));
        let mut srcs = srcs_of(&qs);
        srcs.sort_unstable();
        srcs.dedup();
        assert_eq!(srcs.len(), 64);
    }

    /// `registry_queries` is the registry-driven form of the per-class
    /// helpers: sourced classes draw the exact same source sequence, and
    /// source-free classes repeat their single instance.
    #[test]
    fn registry_queries_match_per_class_helpers() {
        let g = g();
        let reg = AnalysisRegistry::builtin();
        let via_reg = registry_queries(&g, &reg, "bfs", 8, 7).unwrap();
        assert_eq!(srcs_of(&via_reg), srcs_of(&bfs_queries(&g, 8, 7)));
        let via_reg = registry_queries(&g, &reg, "sssp", 8, 7).unwrap();
        assert_eq!(srcs_of(&via_reg), srcs_of(&sssp_queries(&g, 8, 7)));
        let cc = registry_queries(&g, &reg, "cc", 3, 7).unwrap();
        assert_eq!(srcs_of(&cc), srcs_of(&cc_queries(3)));
        assert!(registry_queries(&g, &reg, "betweenness", 1, 7).is_err());
    }

    /// Regression (API migration): `mix_queries` keeps its composition and
    /// order invariants — exact per-class counts, CC spread through the
    /// stream rather than bunched, BFS relative order preserved.
    #[test]
    fn mix_has_right_composition() {
        let g = g();
        let mix = MixPoint { bfs: 17, cc: 5 };
        let qs = mix_queries(&g, mix, 3);
        assert_eq!(qs.len(), 22);
        assert_eq!(qs.iter().filter(|q| q.label() == "cc").count(), 5);
        assert_eq!(qs.iter().filter(|q| q.label() == "bfs").count(), 17);
        // CC queries are spread out, not bunched at the end.
        let first_cc = qs.iter().position(|q| q.label() == "cc").unwrap();
        assert!(first_cc < 10, "first cc at {first_cc}");
        // BFS sub-order matches the standalone plan (sources in seed order).
        let plain = bfs_queries(&g, 17, 3);
        let mixed_bfs: Vec<String> =
            qs.iter().filter(|q| q.label() == "bfs").map(|q| q.to_string()).collect();
        assert_eq!(mixed_bfs, srcs_of(&plain));
    }

    /// Regression (API migration): the sequential baseline ordering still
    /// groups whole classes, BFS first for a BFS+CC mix (§IV-C).
    #[test]
    fn sequential_order_groups_bfs_first() {
        let g = g();
        let qs = mix_queries(&g, MixPoint { bfs: 8, cc: 2 }, 3);
        let seq = sequential_mix_order(&qs);
        assert_eq!(seq.len(), 10);
        assert!(seq[..8].iter().all(|q| q.label() == "bfs"));
        assert!(seq[8..].iter().all(|q| q.label() == "cc"));
    }

    #[test]
    fn sequential_order_is_class_generic() {
        let g = g();
        let stream = interleave_classes(vec![
            khop_queries(&g, 3, 2, 1),
            sssp_queries(&g, 2, 2),
            cc_queries(2),
        ]);
        let seq = sequential_mix_order(&stream);
        let labels: Vec<&str> = seq.iter().map(|q| q.label()).collect();
        // Grouped by class, classes in first-appearance order.
        let first_khop = labels.iter().position(|&l| l == "khop").unwrap();
        let first_sssp = labels.iter().position(|&l| l == "sssp").unwrap();
        let first_cc = labels.iter().position(|&l| l == "cc").unwrap();
        assert!(labels[first_khop..first_khop + 3].iter().all(|&l| l == "khop"));
        assert!(labels[first_sssp..first_sssp + 2].iter().all(|&l| l == "sssp"));
        assert!(labels[first_cc..first_cc + 2].iter().all(|&l| l == "cc"));
        assert_eq!(seq.len(), 7);
    }

    #[test]
    fn interleave_spreads_minority_classes() {
        let g = g();
        let stream =
            interleave_classes(vec![bfs_queries(&g, 12, 5), cc_queries(3)]);
        assert_eq!(stream.len(), 15);
        let cc_positions: Vec<usize> = stream
            .iter()
            .enumerate()
            .filter(|(_, q)| q.label() == "cc")
            .map(|(i, _)| i)
            .collect();
        assert_eq!(cc_positions.len(), 3);
        // Not all bunched at either end.
        assert!(cc_positions[0] < 7, "{cc_positions:?}");
        assert!(*cc_positions.last().unwrap() >= 7, "{cc_positions:?}");
    }

    #[test]
    fn assign_arrivals_sets_each_request() {
        let g = g();
        let mut qs = bfs_queries(&g, 3, 9);
        assign_arrivals(&mut qs, &[1.0, 2.0, 3.0]);
        assert_eq!(qs[0].arrival_ns, 1.0);
        assert_eq!(qs[2].arrival_ns, 3.0);
    }

    #[test]
    fn round_robin_priorities_cycle_all_classes() {
        use crate::coordinator::request::Priority;
        let g = g();
        let mut qs = bfs_queries(&g, 7, 9);
        assign_round_robin_priorities(&mut qs);
        assert_eq!(qs[0].priority, Priority::Interactive);
        assert_eq!(qs[1].priority, Priority::Standard);
        assert_eq!(qs[2].priority, Priority::Batch);
        assert_eq!(qs[3].priority, Priority::Interactive);
        let interactive = qs.iter().filter(|q| q.priority == Priority::Interactive).count();
        assert_eq!(interactive, 3);
    }

    #[test]
    fn arrivals_sorted_and_rate_scaled() {
        let a = arrival_times(1000, 100.0, 9);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // Mean inter-arrival ~ 10 ms = 1e7 ns; total ~ 1e10 ns within 20%.
        let total = *a.last().unwrap();
        assert!((total - 1e10).abs() < 2e9, "total {total}");
        // Reproducible.
        assert_eq!(a, arrival_times(1000, 100.0, 9));
    }
}
