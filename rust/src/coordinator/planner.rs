//! Workload planning: concrete query lists from workload descriptions.

use crate::alg::Query;
use crate::config::workload::MixPoint;
use crate::graph::csr::Csr;
use crate::graph::sample::bfs_sources;
use crate::util::rng::SplitMix64;

/// `k` BFS queries from unique, reproducibly pseudorandom, non-isolated
/// sources (paper §IV-A).
pub fn bfs_queries(g: &Csr, k: usize, seed: u64) -> Vec<Query> {
    bfs_sources(g, k, seed).into_iter().map(|src| Query::Bfs { src }).collect()
}

/// A Table-II style mix: `mix.bfs` BFS queries + `mix.cc` connected
/// components evaluations. The *submission* order interleaves them
/// round-robin-ish (a realistic mixed arrival stream); the paper's
/// sequential baseline ("all the breadth-first searches followed by all the
/// connected components evaluations", §IV-C) is produced by
/// [`sequential_mix_order`].
pub fn mix_queries(g: &Csr, mix: MixPoint, seed: u64) -> Vec<Query> {
    let bfs = bfs_queries(g, mix.bfs, seed);
    let mut out = Vec::with_capacity(mix.total());
    // Spread the CC queries evenly through the BFS stream.
    let stride = if mix.cc == 0 { usize::MAX } else { mix.total().div_ceil(mix.cc) };
    let mut bi = 0;
    let mut placed_cc = 0;
    for i in 0..mix.total() {
        if placed_cc < mix.cc && i % stride == stride - 1 {
            out.push(Query::Cc);
            placed_cc += 1;
        } else if bi < bfs.len() {
            out.push(bfs[bi]);
            bi += 1;
        } else {
            out.push(Query::Cc);
            placed_cc += 1;
        }
    }
    out
}

/// The paper's sequential ordering of a mix: all BFS first, then all CC.
pub fn sequential_mix_order(queries: &[Query]) -> Vec<Query> {
    let mut out: Vec<Query> =
        queries.iter().copied().filter(|q| matches!(q, Query::Bfs { .. })).collect();
    out.extend(queries.iter().copied().filter(|q| matches!(q, Query::Cc)));
    out
}

/// Poisson arrival times: `k` arrivals at `rate_per_s`, reproducible from
/// `seed`. Returns times in ns, sorted.
pub fn arrival_times(k: usize, rate_per_s: f64, seed: u64) -> Vec<f64> {
    assert!(rate_per_s > 0.0, "arrival rate must be positive");
    let mut rng = SplitMix64::new(seed);
    let mut t = 0.0f64;
    (0..k)
        .map(|_| {
            // Inverse-CDF exponential inter-arrival; clamp u away from 0.
            let u = rng.next_f64().max(1e-12);
            t += -u.ln() / rate_per_s * 1e9;
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::rmat::Rmat;

    fn g() -> Csr {
        let r = Rmat::new(GraphConfig::with_scale(10));
        build_undirected_csr(1 << 10, &r.edges())
    }

    #[test]
    fn bfs_queries_unique_sources() {
        let g = g();
        let qs = bfs_queries(&g, 64, 7);
        let mut srcs: Vec<u32> = qs
            .iter()
            .map(|q| match q {
                Query::Bfs { src } => *src,
                _ => panic!("not bfs"),
            })
            .collect();
        srcs.sort_unstable();
        srcs.dedup();
        assert_eq!(srcs.len(), 64);
    }

    #[test]
    fn mix_has_right_composition() {
        let g = g();
        let mix = MixPoint { bfs: 17, cc: 5 };
        let qs = mix_queries(&g, mix, 3);
        assert_eq!(qs.len(), 22);
        assert_eq!(qs.iter().filter(|q| matches!(q, Query::Cc)).count(), 5);
        // CC queries are spread out, not bunched at the end.
        let first_cc = qs.iter().position(|q| matches!(q, Query::Cc)).unwrap();
        assert!(first_cc < 10, "first cc at {first_cc}");
    }

    #[test]
    fn sequential_order_groups_bfs_first() {
        let g = g();
        let qs = mix_queries(&g, MixPoint { bfs: 8, cc: 2 }, 3);
        let seq = sequential_mix_order(&qs);
        assert_eq!(seq.len(), 10);
        assert!(seq[..8].iter().all(|q| matches!(q, Query::Bfs { .. })));
        assert!(seq[8..].iter().all(|q| matches!(q, Query::Cc)));
    }

    #[test]
    fn arrivals_sorted_and_rate_scaled() {
        let a = arrival_times(1000, 100.0, 9);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // Mean inter-arrival ~ 10 ms = 1e7 ns; total ~ 1e10 ns within 20%.
        let total = *a.last().unwrap();
        assert!((total - 1e10).abs() < 2e9, "total {total}");
        // Reproducible.
        assert_eq!(a, arrival_times(1000, 100.0, 9));
    }
}
