//! Scenario compiler: a declarative [`ScenarioSpec`] becomes one merged,
//! deterministic, open-loop arrival timeline (docs/SCENARIOS.md).
//!
//! Each stream gets an RNG rooted at [`stream_seed`] — a hash of the
//! stream *name* mixed with the master seed — so a stream's arrivals,
//! sources and class draws are a pure function of (spec, master seed,
//! name). Two consequences the tests pin:
//!
//! * **Open loop**: arrival instants are computed here, before the engine
//!   runs; nothing about service completions can feed back into them.
//! * **Order independence**: reordering streams inside a spec (or adding
//!   a new stream) cannot change any existing stream's draws, because no
//!   stream's RNG depends on another stream's position or consumption.
//!
//! Compilation resolves each stream's mix against the
//! [`AnalysisRegistry`] into the same [`WorkloadSpec`] machinery the flat
//! `serve` path uses, then merges all streams by arrival instant (ties
//! broken by stream index, then sequence — total and deterministic). The
//! k-th *query* record of the run maps back to the k-th compiled request
//! in every serve path (mutation/compaction records carry their own
//! labels and are filtered out), which is how [`ScenarioStats`] folds
//! per-stream outcomes out of a finished run.

use std::sync::Arc;

use crate::alg::AnalysisRegistry;
use crate::config::scenario::ScenarioSpec;
use crate::coordinator::metrics::QueryRecord;
use crate::coordinator::request::QueryRequest;
use crate::coordinator::service::{WorkloadClass, WorkloadSpec};
use crate::graph::csr::Csr;
use crate::util::json::Json;
use crate::util::rng::SplitMix64;
use crate::util::stats::Quantiles;

/// The per-stream RNG seed: FNV-1a of the stream name, XORed with the
/// master seed, finalized through one SplitMix64 step (names differing in
/// one byte land far apart). Surfaced per stream in the service report so
/// any single stream's draw sequence is reproducible from the summary.
pub fn stream_seed(master_seed: u64, name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in name.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    SplitMix64::new(h ^ master_seed).next_u64()
}

/// One compiled stream's identity in the merged timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct CompiledStream {
    pub name: String,
    /// The stream's root RNG seed ([`stream_seed`]).
    pub seed: u64,
    /// Arrivals this stream contributed.
    pub arrivals: usize,
}

/// Maps merged-timeline positions back to streams (what
/// [`ScenarioStats`] needs from compilation, kept after the request
/// vector is handed to the engine).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioMap {
    /// `stream_of[i]` = index into `streams` of the i-th merged request.
    pub stream_of: Vec<usize>,
    pub streams: Vec<CompiledStream>,
}

/// A compiled scenario: the merged request timeline plus the stream map.
pub struct ScenarioTimeline {
    /// Requests in arrival order (`requests[i].arrival_ns == arrivals[i]`).
    pub requests: Vec<QueryRequest>,
    /// Sorted arrival instants (ns), parallel to `requests`.
    pub arrivals: Vec<f64>,
    pub map: ScenarioMap,
}

/// Compile `spec` against a graph and registry into a merged timeline.
///
/// Per stream, the root RNG forks two independent sub-streams: `0xA1`
/// drives the arrival process and `0xB2` drives the per-request draws
/// (class, source, nothing else) — so a stream's arrival *instants* are
/// independent even of its own mix, and the open-loop property test can
/// compare timelines across serving policies bit-for-bit. Sources are
/// rejection-sampled non-isolated vertices *with* repeats (arrival counts
/// are random, so the distinct-source sampler's cardinality precondition
/// can't be promised here).
pub fn compile(
    g: &Csr,
    registry: &AnalysisRegistry,
    spec: &ScenarioSpec,
    master_seed: u64,
) -> anyhow::Result<ScenarioTimeline> {
    spec.validate()?;
    let n = g.n() as u64;
    anyhow::ensure!(n > 0, "cannot compile a scenario against an empty graph");

    let mut streams = Vec::with_capacity(spec.streams.len());
    // (arrival ns, stream index, in-stream sequence, request)
    let mut merged: Vec<(f64, usize, usize, QueryRequest)> = Vec::new();
    for (si, stream) in spec.streams.iter().enumerate() {
        let classes = stream
            .mix
            .iter()
            .map(|(label, w)| WorkloadClass::from_registry(registry, label, *w))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let workload = WorkloadSpec::new(classes);
        workload.validate()?;

        let seed = stream_seed(master_seed, &stream.name);
        let mut root = SplitMix64::new(seed);
        let mut arr_rng = root.fork(0xA1);
        let mut req_rng = root.fork(0xB2);
        let arrivals = stream.process.sample_arrivals_ns(spec.duration_s, &mut arr_rng);
        let mut attempts_left = 10_000u64 + 1_000 * arrivals.len() as u64;
        for (seq, &t) in arrivals.iter().enumerate() {
            let class = workload.pick(&mut req_rng);
            let src = loop {
                anyhow::ensure!(
                    attempts_left > 0,
                    "stream {:?}: could not find non-isolated source vertices \
                     (graph too sparse)",
                    stream.name
                );
                attempts_left -= 1;
                let v = req_rng.gen_range(n) as u32;
                if g.degree(v) > 0 {
                    break v;
                }
            };
            let priority = stream.priority.unwrap_or(class.priority);
            let mut req =
                QueryRequest::from_arc(class.build(src)).at(t).with_priority(priority);
            if let Some(d) = stream.deadline_s.or(class.deadline_s) {
                req = req.with_deadline_ns(d * 1e9);
            }
            merged.push((t, si, seq, req));
        }
        streams.push(CompiledStream { name: stream.name.clone(), seed, arrivals: arrivals.len() });
    }
    anyhow::ensure!(
        !merged.is_empty(),
        "scenario {:?} generated no arrivals with seed {master_seed:#x} \
         (raise rates or duration, or compress less)",
        spec.name
    );
    anyhow::ensure!(
        merged.len() <= crate::config::scenario::MAX_STREAM_ARRIVALS,
        "scenario {:?} generated {} arrivals (cap {}); compress time or lower rates",
        spec.name,
        merged.len(),
        crate::config::scenario::MAX_STREAM_ARRIVALS
    );
    // Total order: instant, then stream index, then in-stream sequence.
    // f64 total_cmp keeps the sort total even at exact ties.
    merged.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));

    let arrivals: Vec<f64> = merged.iter().map(|m| m.0).collect();
    let stream_of: Vec<usize> = merged.iter().map(|m| m.1).collect();
    let requests: Vec<QueryRequest> = merged.into_iter().map(|m| m.3).collect();
    Ok(ScenarioTimeline { requests, arrivals, map: ScenarioMap { stream_of, streams } })
}

/// Per-stream outcome summary of a finished scenario run.
#[derive(Debug, Clone)]
pub struct StreamStats {
    pub name: String,
    /// Root RNG seed of the stream (reproduce it alone via [`stream_seed`]).
    pub seed: u64,
    pub arrivals: usize,
    pub completed: usize,
    pub rejected: usize,
    pub shed: usize,
    /// Completed after at least one checkpoint park (subset of `completed`).
    pub preempted: usize,
    /// Latency quantiles of the stream's completed queries (s).
    pub latency: Option<Quantiles>,
    /// The stream's declared p99 target (s), if any.
    pub slo_p99_s: Option<f64>,
    /// SLO verdict: None when no target declared; `Some(false)` when a
    /// target exists but nothing completed (an SLO cannot pass vacuously
    /// while its stream is being starved).
    pub slo_pass: Option<bool>,
}

impl StreamStats {
    /// One operator summary line.
    pub fn line(&self) -> String {
        let mut out = format!(
            "stream {:>12} (seed {:#018x}): {} arrivals — {} ok, {} rejected, {} shed, \
             {} preempted",
            self.name, self.seed, self.arrivals, self.completed, self.rejected, self.shed,
            self.preempted,
        );
        if let Some(q) = &self.latency {
            out.push_str(&format!(" | p50={:.3}s p99={:.3}s", q.q50, q.q99));
        }
        if let (Some(t), Some(pass)) = (self.slo_p99_s, self.slo_pass) {
            out.push_str(&format!(
                " | SLO p99<={t:.3}s: {}",
                if pass { "PASS" } else { "FAIL" }
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let q_or_null = |v: Option<f64>| v.map_or(Json::Null, Json::num);
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("seed", Json::str(format!("{:#x}", self.seed))),
            ("arrivals", Json::num(self.arrivals as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("preempted", Json::num(self.preempted as f64)),
            ("p50_s", q_or_null(self.latency.as_ref().map(|q| q.q50))),
            ("p95_s", q_or_null(self.latency.as_ref().map(|q| q.q95))),
            ("p99_s", q_or_null(self.latency.as_ref().map(|q| q.q99))),
            ("slo_p99_s", q_or_null(self.slo_p99_s)),
            (
                "slo_pass",
                self.slo_pass.map_or(Json::Null, Json::Bool),
            ),
        ])
    }
}

/// Scenario section of a service report: identity plus per-stream stats.
#[derive(Debug, Clone)]
pub struct ScenarioStats {
    pub name: String,
    /// Arrival-window length (simulated s) after any time compression.
    pub duration_s: f64,
    pub streams: Vec<StreamStats>,
}

impl ScenarioStats {
    /// Fold per-stream outcomes out of a finished run. `records` must be
    /// the run's *query* records (mutation/compaction lanes filtered out)
    /// in original submission order — position k is compiled request k,
    /// the invariant every serve path maintains.
    pub fn from_records(
        spec: &ScenarioSpec,
        map: &ScenarioMap,
        records: &[&QueryRecord],
    ) -> ScenarioStats {
        assert_eq!(
            records.len(),
            map.stream_of.len(),
            "query records must map 1:1 onto compiled scenario requests"
        );
        let mut streams: Vec<StreamStats> = map
            .streams
            .iter()
            .zip(&spec.streams)
            .map(|(c, s)| {
                debug_assert_eq!(c.name, s.name, "map and spec streams stay parallel");
                StreamStats {
                    name: c.name.clone(),
                    seed: c.seed,
                    arrivals: c.arrivals,
                    completed: 0,
                    rejected: 0,
                    shed: 0,
                    preempted: 0,
                    latency: None,
                    slo_p99_s: s.slo_p99_s,
                    slo_pass: None,
                }
            })
            .collect();
        let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); streams.len()];
        for (r, &si) in records.iter().zip(&map.stream_of) {
            let st = &mut streams[si];
            if r.completed() {
                st.completed += 1;
                latencies[si].push(r.latency_s);
            }
            if r.rejected() {
                st.rejected += 1;
            }
            if r.shed() {
                st.shed += 1;
            }
            if r.preempted() {
                st.preempted += 1;
            }
        }
        for (st, xs) in streams.iter_mut().zip(&latencies) {
            st.latency = Quantiles::try_from_samples(xs);
            st.slo_pass = st.slo_p99_s.map(|target| {
                st.latency.as_ref().is_some_and(|q| q.q99 <= target)
            });
        }
        ScenarioStats { name: spec.name.clone(), duration_s: spec.duration_s, streams }
    }

    /// Every stream with a declared SLO met it.
    pub fn slos_pass(&self) -> bool {
        self.streams.iter().all(|s| s.slo_pass != Some(false))
    }

    /// Stats of one stream by name.
    pub fn stream(&self, name: &str) -> Option<&StreamStats> {
        self.streams.iter().find(|s| s.name == name)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("duration_s", Json::num(self.duration_s)),
            ("streams", Json::arr(self.streams.iter().map(|s| s.to_json()))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::rmat::Rmat;

    fn g() -> Csr {
        let r = Rmat::new(GraphConfig::with_scale(10));
        build_undirected_csr(1 << 10, &r.edges())
    }

    #[test]
    fn compile_is_deterministic_and_sorted() {
        let g = g();
        let reg = AnalysisRegistry::builtin();
        let spec = ScenarioSpec::builtin("steady").unwrap();
        let a = compile(&g, &reg, &spec, 7).unwrap();
        let b = compile(&g, &reg, &spec, 7).unwrap();
        assert_eq!(a.arrivals.len(), b.arrivals.len());
        for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert!(a.arrivals.windows(2).all(|w| w[0] <= w[1]), "merged timeline sorted");
        assert_eq!(a.requests.len(), a.map.stream_of.len());
        assert_eq!(
            a.map.streams.iter().map(|s| s.arrivals).sum::<usize>(),
            a.requests.len(),
            "per-stream counts partition the merged timeline"
        );
        // Requests carry their merged arrival instants.
        for (req, &t) in a.requests.iter().zip(&a.arrivals) {
            assert_eq!(req.arrival_ns.to_bits(), t.to_bits());
        }
        // A different master seed moves the arrivals.
        let c = compile(&g, &reg, &spec, 8).unwrap();
        assert_ne!(
            a.arrivals.iter().map(|t| t.to_bits()).collect::<Vec<_>>(),
            c.arrivals.iter().map(|t| t.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn stream_seed_depends_on_name_and_master() {
        assert_ne!(stream_seed(1, "a"), stream_seed(1, "b"));
        assert_ne!(stream_seed(1, "a"), stream_seed(2, "a"));
        assert_eq!(stream_seed(1, "a"), stream_seed(1, "a"));
    }

    #[test]
    fn streams_carry_their_declared_metadata() {
        let g = g();
        let reg = AnalysisRegistry::builtin();
        let spec = ScenarioSpec::builtin("overload-ramp").unwrap();
        let tl = compile(&g, &reg, &spec, 11).unwrap();
        use crate::coordinator::request::Priority;
        for (req, &si) in tl.requests.iter().zip(&tl.map.stream_of) {
            let stream = &spec.streams[si];
            match stream.name.as_str() {
                "interactive-frontend" => {
                    assert_eq!(req.priority, Priority::Interactive);
                    assert_eq!(req.label(), "khop");
                    assert!(req.deadline_ns.is_none());
                }
                "batch-ingest-ramp" => {
                    assert_eq!(req.priority, Priority::Batch);
                    assert_eq!(req.label(), "bfs");
                    assert_eq!(req.deadline_ns, Some(0.5 * 1e9));
                }
                other => panic!("unexpected stream {other}"),
            }
        }
    }
}
