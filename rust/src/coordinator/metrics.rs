//! Run reports: per-query records, per-class quantiles (Table I),
//! improvement percentages (Fig. 4), and counter summaries.
//!
//! Reporting is class-generic: records carry the analysis label from the
//! request, and quantiles are available for any label that ran — a new
//! [`crate::alg::Analysis`] shows up in reports without any change here.

use crate::coordinator::request::{Priority, QueryRequest};
use crate::sim::counters::Counters;
use crate::sim::flow::FlowReport;
use crate::sim::machine::Machine;
use crate::util::stats::{improvement_pct, Quantiles};

/// How admission disposed of one query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Ran to completion (never preempted).
    Completed,
    /// Refused at arrival (admission full under reject, or a footprint
    /// larger than the machine's whole context memory).
    Rejected,
    /// Admitted to the wait queue but dropped before starting: deadline
    /// expired while waiting, or shed under overload (Batch first).
    Shed,
    /// Checkpoint-parked at least once under Interactive pressure (see
    /// [`crate::sim::preempt`]). `resumed: true` — the normal case — means
    /// it was resumed from its checkpoint and ran to completion, with the
    /// parked time inside its latency.
    Preempted {
        /// Whether the query resumed and completed (the engine drains the
        /// parked set before finishing, so this is true in practice).
        resumed: bool,
    },
}

/// One executed query's outcome.
#[derive(Debug, Clone)]
pub struct QueryRecord {
    pub id: usize,
    /// Analysis class label ("bfs", "cc", "sssp", ...).
    pub label: &'static str,
    /// Priority class the request declared.
    pub priority: Priority,
    /// Class admission actually served the query as: the declared class,
    /// or `Interactive` when anti-starvation aging promoted it out of the
    /// wait queue. Recording both keeps per-class statistics honest — the
    /// promoted query's wait still counts against its declared class, and
    /// [`PriorityStats::promoted`] surfaces how often aging fired.
    pub admitted_as: Priority,
    /// Latency deadline (s from arrival), if the request had one.
    pub deadline_s: Option<f64>,
    /// End-to-end latency in seconds (arrival to completion), NaN if the
    /// query never ran.
    pub latency_s: f64,
    /// Arrival time (s) within the run.
    pub arrival_s: f64,
    /// First-progress time (s) within the run; NaN = never started. The
    /// gap to `arrival_s` is the admission wait.
    pub start_s: f64,
    /// Completion time (s) within the run, NaN if the query never ran.
    pub finish_s: f64,
    /// How admission disposed of the query.
    pub outcome: Outcome,
}

impl QueryRecord {
    /// Ran to completion — directly, or after a preempt/resume round trip.
    pub fn completed(&self) -> bool {
        matches!(self.outcome, Outcome::Completed | Outcome::Preempted { resumed: true })
    }

    pub fn rejected(&self) -> bool {
        self.outcome == Outcome::Rejected
    }

    pub fn shed(&self) -> bool {
        self.outcome == Outcome::Shed
    }

    /// Checkpoint-parked at least once.
    pub fn preempted(&self) -> bool {
        matches!(self.outcome, Outcome::Preempted { .. })
    }

    /// Aging admitted this query as a better class than it declared.
    pub fn promoted(&self) -> bool {
        self.admitted_as < self.priority
    }

    /// Admission wait: arrival to first progress (s). NaN if the query
    /// never started.
    pub fn wait_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }

    /// Completed but blew its deadline.
    pub fn missed_deadline(&self) -> bool {
        match self.deadline_s {
            Some(d) => self.completed() && self.latency_s > d,
            None => false,
        }
    }
}

/// Per-priority-class admission summary of a run, keyed by *declared*
/// class (a promoted query's wait and latency stay with the class the
/// caller asked for; `promoted` counts how often aging re-classed it).
#[derive(Debug, Clone)]
pub struct PriorityStats {
    pub priority: Priority,
    /// Requests submitted in this class.
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    pub shed: usize,
    /// Queries checkpoint-parked at least once (all resumed).
    pub preempted: usize,
    /// Queries aging admitted as a better class than declared.
    pub promoted: usize,
    /// Mean admission wait over queries that started (s); 0 if none did.
    pub mean_wait_s: f64,
    /// Latency quantiles of completed queries, if any.
    pub latency: Option<Quantiles>,
}

impl PriorityStats {
    /// One operator-facing report line (shared by the CLI `run` output
    /// and [`crate::coordinator::ServiceReport::summary`]).
    pub fn line(&self) -> String {
        format!(
            "[{}] {} submitted, {} done, {} shed, {} rejected, {} preempted, \
             {} aged-up, mean wait {:.4}s",
            self.priority,
            self.submitted,
            self.completed,
            self.shed,
            self.rejected,
            self.preempted,
            self.promoted,
            self.mean_wait_s
        )
    }
}

/// Outcome of one coordinated run (one policy, one machine, one batch).
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Policy label: "sequential", "concurrent", or an admitted variant
    /// carrying the overload behavior and the effective byte budget —
    /// "concurrent(queue, cap=4080MiB)", "concurrent(reject, cap=…)",
    /// "concurrent(shed<=N, cap=…)" (see
    /// [`crate::coordinator::Policy::label`]).
    pub policy: String,
    /// Machine preset name.
    pub machine: String,
    pub records: Vec<QueryRecord>,
    /// End-to-end time of the whole run (s).
    pub makespan_s: f64,
    /// Peak concurrency observed inside the engine.
    pub peak_concurrency: usize,
    /// Simulated hardware counters for the run.
    pub counters: Counters,
    /// Mean channel utilization over the run (the paper's thesis variable).
    pub mean_channel_utilization: f64,
}

impl RunReport {
    /// Build from a flow-engine report (one timing per request — the
    /// unbatched 1:1 case, delegating to [`RunReport::from_flow_grouped`]
    /// with the identity map).
    pub fn from_flow(
        policy: impl Into<String>,
        machine: &Machine,
        requests: &[QueryRequest],
        flow: &FlowReport,
    ) -> Self {
        assert_eq!(requests.len(), flow.timings.len());
        let identity: Vec<usize> = (0..requests.len()).collect();
        Self::from_flow_grouped(policy, machine, requests, &identity, flow)
    }

    /// Build from a flow-engine report where requests were **fused** into
    /// fewer engine queries (DESIGN.md §Batching): `group_of[i]` names the
    /// timing that served original request `i`. Every member request gets
    /// its OWN record — its own label, declared priority, deadline, and
    /// arrival — while start/finish/admitted-as come from the fused
    /// timing, so a member's latency is `fused finish − member arrival`
    /// (the wait for the batch window is inside it) and a shed or
    /// preempted batch disposes every member identically. The member
    /// latencies therefore partition exactly under [`Outcome`] accounting,
    /// which the batching tests pin.
    pub fn from_flow_grouped(
        policy: impl Into<String>,
        machine: &Machine,
        requests: &[QueryRequest],
        group_of: &[usize],
        flow: &FlowReport,
    ) -> Self {
        assert_eq!(requests.len(), group_of.len());
        let shed: std::collections::HashSet<usize> = flow.shed.iter().copied().collect();
        let preempted: std::collections::HashSet<usize> = flow.preempted.iter().copied().collect();
        let records = requests
            .iter()
            .zip(group_of)
            .enumerate()
            .map(|(i, (req, &gi))| {
                let t = &flow.timings[gi];
                QueryRecord {
                    id: i,
                    label: req.label(),
                    priority: req.priority,
                    admitted_as: t.admitted_as,
                    deadline_s: req.deadline_ns.map(|d| d * 1e-9),
                    latency_s: (t.finish_ns - req.arrival_ns) * 1e-9,
                    arrival_s: req.arrival_ns * 1e-9,
                    start_s: t.start_ns * 1e-9,
                    finish_s: t.finish_ns * 1e-9,
                    outcome: if preempted.contains(&t.id) {
                        Outcome::Preempted { resumed: t.completed() }
                    } else if t.completed() {
                        Outcome::Completed
                    } else if shed.contains(&t.id) {
                        Outcome::Shed
                    } else {
                        Outcome::Rejected
                    },
                }
            })
            .collect();
        let mean_channel_utilization = flow.counters.mean_channel_utilization(machine);
        RunReport {
            policy: policy.into(),
            machine: machine.cfg.name.clone(),
            records,
            makespan_s: flow.makespan_ns * 1e-9,
            peak_concurrency: flow.peak_concurrency,
            counters: flow.counters.clone(),
            mean_channel_utilization,
        }
    }

    /// Completed query count.
    pub fn completed(&self) -> usize {
        self.records.iter().filter(|r| r.completed()).count()
    }

    /// Queries rejected at arrival.
    pub fn rejections(&self) -> usize {
        self.records.iter().filter(|r| r.rejected()).count()
    }

    /// Queries shed from the wait queue (deadline expiry or overload).
    pub fn sheds(&self) -> usize {
        self.records.iter().filter(|r| r.shed()).count()
    }

    /// Queries checkpoint-parked at least once (a subset of
    /// [`RunReport::completed`] — parked work resumes and finishes).
    pub fn preempted(&self) -> usize {
        self.records.iter().filter(|r| r.preempted()).count()
    }

    /// Queries aging admitted as a better class than they declared.
    pub fn promoted(&self) -> usize {
        self.records.iter().filter(|r| r.promoted()).count()
    }

    /// Completed queries whose deadline was exceeded.
    pub fn deadline_misses(&self) -> usize {
        self.records.iter().filter(|r| r.missed_deadline()).count()
    }

    /// Per-priority-class admission summary, best-served class first;
    /// classes with no submissions are omitted.
    pub fn priority_stats(&self) -> Vec<PriorityStats> {
        Priority::ALL.iter().filter_map(|&p| self.priority_class(p)).collect()
    }

    /// Admission summary of one priority class, if it had submissions.
    pub fn priority_class(&self, priority: Priority) -> Option<PriorityStats> {
        let rs: Vec<&QueryRecord> =
            self.records.iter().filter(|r| r.priority == priority).collect();
        if rs.is_empty() {
            return None;
        }
        let waits: Vec<f64> =
            rs.iter().filter(|r| r.start_s.is_finite()).map(|r| r.wait_s()).collect();
        let lats: Vec<f64> = rs.iter().filter(|r| r.completed()).map(|r| r.latency_s).collect();
        Some(PriorityStats {
            priority,
            submitted: rs.len(),
            completed: rs.iter().filter(|r| r.completed()).count(),
            rejected: rs.iter().filter(|r| r.rejected()).count(),
            shed: rs.iter().filter(|r| r.shed()).count(),
            preempted: rs.iter().filter(|r| r.preempted()).count(),
            promoted: rs.iter().filter(|r| r.promoted()).count(),
            mean_wait_s: crate::util::stats::try_mean(&waits).unwrap_or(0.0),
            latency: Quantiles::try_from_samples(&lats),
        })
    }

    /// Distinct analysis labels in submission order of first appearance.
    pub fn labels(&self) -> Vec<&'static str> {
        crate::coordinator::request::distinct_labels(self.records.iter().map(|r| r.label))
    }

    /// Latencies (s) of completed queries, optionally filtered by label.
    pub fn latencies(&self, label: Option<&str>) -> Vec<f64> {
        self.records
            .iter()
            .filter(|r| r.completed())
            .filter(|r| label.is_none_or(|l| r.label == l))
            .map(|r| r.latency_s)
            .collect()
    }

    /// Quantile summary of per-query latency (s), optionally filtered by
    /// label. None if no completed query matches.
    pub fn latency_quantiles(&self, label: Option<&str>) -> Option<Quantiles> {
        Quantiles::try_from_samples(&self.latencies(label))
    }

    /// Latency quantiles of every class that completed at least one query,
    /// in submission order of first appearance.
    pub fn per_class_quantiles(&self) -> Vec<(&'static str, Quantiles)> {
        self.labels()
            .into_iter()
            .filter_map(|l| self.latency_quantiles(Some(l)).map(|q| (l, q)))
            .collect()
    }

    /// Mean completed-query latency (s), or `None` if nothing completed
    /// (the old version panicked on a fully-shed run).
    pub fn mean_latency_s(&self) -> Option<f64> {
        crate::util::stats::try_mean(&self.latencies(None))
    }

    /// Completed queries per second of makespan.
    pub fn throughput_qps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            return 0.0;
        }
        self.completed() as f64 / self.makespan_s
    }

    /// Standardized per-scenario report table (markdown), the repeatable
    /// format the ROADMAP's reporting item calls for (modeled on
    /// postgresflow's `docs/BENCHMARKING.md`): one row per analysis
    /// label with tail quantiles, shed counts and disposition notes.
    /// `n/a` marks a scenario that completed nothing — distinguishable
    /// from a true zero-latency run.
    ///
    /// ```text
    /// | scenario | p50 (s) | p95 (s) | p99 (s) | sheds | notes |
    /// |---|---:|---:|---:|---:|---|
    /// | bfs | 0.011200 | 0.019800 | 0.021000 | 0 | 24/24 completed |
    /// ```
    pub fn report_table(&self) -> String {
        let mut out = String::from(
            "| scenario | p50 (s) | p95 (s) | p99 (s) | sheds | notes |\n\
             |---|---:|---:|---:|---:|---|\n",
        );
        let fmt = |x: Option<f64>| match x {
            Some(v) => format!("{v:.6}"),
            None => "n/a".to_string(),
        };
        for label in self.labels() {
            let rs: Vec<&QueryRecord> =
                self.records.iter().filter(|r| r.label == label).collect();
            let q = self.latency_quantiles(Some(label));
            let sheds = rs.iter().filter(|r| r.shed()).count();
            let completed = rs.iter().filter(|r| r.completed()).count();
            let mut notes = format!("{completed}/{} completed", rs.len());
            let rejected = rs.iter().filter(|r| r.rejected()).count();
            if rejected > 0 {
                notes.push_str(&format!(", {rejected} rejected"));
            }
            let preempted = rs.iter().filter(|r| r.preempted()).count();
            if preempted > 0 {
                notes.push_str(&format!(", {preempted} preempted"));
            }
            let misses = rs.iter().filter(|r| r.missed_deadline()).count();
            if misses > 0 {
                notes.push_str(&format!(", {misses} deadline misses"));
            }
            out.push_str(&format!(
                "| {label} | {} | {} | {} | {sheds} | {notes} |\n",
                fmt(q.map(|q| q.q50)),
                fmt(q.map(|q| q.q95)),
                fmt(q.map(|q| q.q99)),
            ));
        }
        out
    }
}

/// A paired sequential/concurrent comparison row (Fig. 3/4, Table II).
#[derive(Debug, Clone)]
pub struct ImprovementRow {
    pub machine: String,
    pub queries: usize,
    pub concurrent_s: f64,
    pub sequential_s: f64,
}

impl ImprovementRow {
    pub fn from_reports(conc: &RunReport, seq: &RunReport) -> Self {
        assert_eq!(conc.machine, seq.machine);
        ImprovementRow {
            machine: conc.machine.clone(),
            queries: conc.records.len(),
            concurrent_s: conc.makespan_s,
            sequential_s: seq.makespan_s,
        }
    }

    /// The paper's "% improvement of concurrent over sequential".
    pub fn improvement_pct(&self) -> f64 {
        improvement_pct(self.sequential_s, self.concurrent_s)
    }

    /// Speed-up factor (sequential / concurrent).
    pub fn speedup(&self) -> f64 {
        self.sequential_s / self.concurrent_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::{Bfs, Cc};
    use crate::config::machine::MachineConfig;
    use crate::sim::flow::QueryTiming;

    fn machine() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn flow_with(latencies_ns: &[f64]) -> (Vec<QueryRequest>, FlowReport) {
        let timings: Vec<QueryTiming> = latencies_ns
            .iter()
            .enumerate()
            .map(|(i, &l)| QueryTiming {
                id: i,
                label: "bfs",
                arrival_ns: 0.0,
                start_ns: 0.0,
                finish_ns: l,
                phases: 1,
                priority: Priority::Standard,
                admitted_as: Priority::Standard,
            })
            .collect();
        let makespan = latencies_ns.iter().copied().fold(0.0, f64::max);
        let requests: Vec<QueryRequest> =
            latencies_ns.iter().map(|_| QueryRequest::new(Bfs { src: 0 })).collect();
        let flow = FlowReport {
            timings,
            makespan_ns: makespan,
            counters: Counters::new(8),
            peak_concurrency: latencies_ns.len(),
            rejected: vec![],
            shed: vec![],
            peak_ctx_bytes: 0,
            preempted: vec![],
            parks: 0,
            resumes: 0,
            weights: crate::sim::flow::ShareWeights::flat(),
            events: 0,
        };
        (requests, flow)
    }

    #[test]
    fn report_aggregates_latencies() {
        let (qs, flow) = flow_with(&[1e9, 2e9, 3e9, 4e9]);
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.completed(), 4);
        assert_eq!(rep.rejections(), 0);
        let q = rep.latency_quantiles(Some("bfs")).unwrap();
        assert_eq!(q.q0, 1.0);
        assert_eq!(q.q100, 4.0);
        assert_eq!(rep.makespan_s, 4.0);
        assert_eq!(rep.throughput_qps(), 1.0);
        assert!(rep.latency_quantiles(Some("cc")).is_none());
        assert_eq!(rep.labels(), vec!["bfs"]);
        let per_class = rep.per_class_quantiles();
        assert_eq!(per_class.len(), 1);
        assert_eq!(per_class[0].0, "bfs");
    }

    #[test]
    fn rejected_queries_excluded() {
        let (qs, mut flow) = flow_with(&[1e9, 2e9]);
        flow.timings[1].finish_ns = f64::NAN;
        flow.timings[1].start_ns = f64::NAN;
        flow.rejected = vec![1];
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.completed(), 1);
        assert_eq!(rep.rejections(), 1);
        assert_eq!(rep.sheds(), 0);
        assert_eq!(rep.records[1].outcome, Outcome::Rejected);
        assert!(rep.records[1].wait_s().is_nan());
        assert_eq!(rep.latencies(None), vec![1.0]);
    }

    #[test]
    fn shed_and_rejected_are_distinct_outcomes() {
        let (qs, mut flow) = flow_with(&[1e9, 2e9, 3e9]);
        for i in [1, 2] {
            flow.timings[i].finish_ns = f64::NAN;
            flow.timings[i].start_ns = f64::NAN;
        }
        flow.rejected = vec![1];
        flow.shed = vec![2];
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.completed(), 1);
        assert_eq!(rep.rejections(), 1);
        assert_eq!(rep.sheds(), 1);
        assert!(rep.records[2].shed() && !rep.records[2].rejected());
        // A shed query never completes, so it cannot "miss" a deadline.
        assert_eq!(rep.deadline_misses(), 0);
    }

    #[test]
    fn priority_stats_split_by_class() {
        let (mut qs, mut flow) = flow_with(&[1e9, 2e9, 3e9, 4e9]);
        qs[0] = qs[0].clone().with_priority(Priority::Interactive);
        qs[3] = qs[3].clone().with_priority(Priority::Batch);
        // The batch query waited 1 s before starting; the rest started at
        // arrival.
        flow.timings[3].start_ns = 1e9;
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        let stats = rep.priority_stats();
        assert_eq!(stats.len(), 3);
        assert_eq!(stats[0].priority, Priority::Interactive);
        assert_eq!(stats[0].submitted, 1);
        assert_eq!(stats[2].priority, Priority::Batch);
        assert!((stats[2].mean_wait_s - 1.0).abs() < 1e-12);
        assert!((stats[1].mean_wait_s - 0.0).abs() < 1e-12);
        let batch = rep.priority_class(Priority::Batch).unwrap();
        assert_eq!(batch.completed, 1);
        assert!(batch.latency.is_some());
    }

    #[test]
    fn deadline_misses_counted() {
        let (mut qs, flow) = flow_with(&[1e9, 2e9, 3e9]);
        qs[0] = qs[0].clone().with_deadline_ns(5e8); // 0.5 s budget, 1 s latency
        qs[1] = qs[1].clone().with_deadline_ns(4e9); // met
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.deadline_misses(), 1);
        assert!(rep.records[0].missed_deadline());
        assert!(!rep.records[1].missed_deadline());
        assert!(!rep.records[2].missed_deadline()); // no deadline set
    }

    /// The four dispositions partition the batch exactly, and a
    /// preempted-then-resumed query counts as completed work.
    #[test]
    fn preempted_resumed_partition_stays_exact() {
        let (qs, mut flow) = flow_with(&[1e9, 2e9, 3e9, 4e9]);
        // Query 1 was parked and resumed; 2 rejected; 3 shed.
        flow.preempted = vec![1];
        flow.parks = 1;
        flow.resumes = 1;
        for i in [2, 3] {
            flow.timings[i].finish_ns = f64::NAN;
            flow.timings[i].start_ns = f64::NAN;
        }
        flow.rejected = vec![2];
        flow.shed = vec![3];
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.records[1].outcome, Outcome::Preempted { resumed: true });
        assert!(rep.records[1].completed(), "resumed work is completed work");
        assert_eq!(rep.preempted(), 1);
        assert_eq!(rep.completed(), 2);
        assert_eq!(rep.completed() + rep.rejections() + rep.sheds(), 4);
        // Outcome variants partition exactly: one record per disposition.
        let by_outcome =
            |pred: fn(&QueryRecord) -> bool| rep.records.iter().filter(|r| pred(r)).count();
        assert_eq!(by_outcome(|r| r.outcome == Outcome::Completed), 1);
        assert_eq!(by_outcome(QueryRecord::preempted), 1);
        assert_eq!(by_outcome(QueryRecord::rejected), 1);
        assert_eq!(by_outcome(QueryRecord::shed), 1);
        // Per-class stats see the preempted query too.
        let stats = rep.priority_class(Priority::Standard).unwrap();
        assert_eq!(stats.preempted, 1);
        assert_eq!(stats.completed, 2);
    }

    /// Bugfix (aging accounting): the record carries both the declared
    /// class and the admitted-as class, and `promoted` counts the gap.
    #[test]
    fn promoted_queries_counted_per_declared_class() {
        let (mut qs, mut flow) = flow_with(&[1e9, 2e9, 3e9]);
        qs[1] = qs[1].clone().with_priority(Priority::Batch);
        qs[2] = qs[2].clone().with_priority(Priority::Batch);
        for i in [1, 2] {
            flow.timings[i].priority = Priority::Batch;
            flow.timings[i].admitted_as = Priority::Batch;
        }
        // Query 1 aged into the Interactive class before starting.
        flow.timings[1].admitted_as = Priority::Interactive;
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert!(rep.records[1].promoted() && !rep.records[2].promoted());
        assert_eq!(rep.promoted(), 1);
        // The promoted query still reports under its declared class.
        let batch = rep.priority_class(Priority::Batch).unwrap();
        assert_eq!(batch.submitted, 2);
        assert_eq!(batch.promoted, 1);
        assert!(rep.priority_class(Priority::Interactive).is_none(), "declared-class keying");
        assert!(batch.line().contains("aged-up"));
    }

    #[test]
    fn labels_preserve_first_appearance_order() {
        let (mut qs, flow) = flow_with(&[1e9, 2e9, 3e9]);
        qs[1] = QueryRequest::new(Cc);
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.labels(), vec!["bfs", "cc"]);
    }

    /// Bugfix: a run where nothing completed used to panic in
    /// `mean_latency_s` (empty mean) — now it reports `None`, and the
    /// report table renders `n/a` instead of a fake 0.000000.
    #[test]
    fn empty_completion_set_reports_none_not_zero() {
        let (qs, mut flow) = flow_with(&[1e9, 2e9]);
        for i in [0, 1] {
            flow.timings[i].finish_ns = f64::NAN;
            flow.timings[i].start_ns = f64::NAN;
        }
        flow.shed = vec![0, 1];
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.completed(), 0);
        assert_eq!(rep.mean_latency_s(), None);
        assert!(rep.latency_quantiles(None).is_none());
        let table = rep.report_table();
        assert!(table.contains("| bfs | n/a | n/a | n/a | 2 | 0/2 completed |"), "{table}");
    }

    #[test]
    fn report_table_renders_quantiles_and_notes() {
        let (mut qs, mut flow) = flow_with(&[1e9, 2e9, 3e9, 4e9]);
        qs[3] = QueryRequest::new(Cc);
        flow.timings[3].finish_ns = f64::NAN;
        flow.timings[3].start_ns = f64::NAN;
        flow.shed = vec![3];
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        let table = rep.report_table();
        assert!(table.starts_with("| scenario | p50 (s) | p95 (s) | p99 (s) | sheds | notes |"));
        assert!(table.contains("| bfs | 2.000000 |"), "{table}");
        assert!(table.contains("| cc | n/a | n/a | n/a | 1 | 0/1 completed |"), "{table}");
        assert!(table.contains("3/3 completed"), "{table}");
    }

    /// Batched fan-out: members of a fused timing keep their own labels,
    /// arrivals and deadlines; latency = fused finish − member arrival;
    /// a shed fused query sheds every member.
    #[test]
    fn grouped_fan_out_keeps_per_member_records() {
        // Three requests served by two timings: [0, 1] fused, [2] solo.
        let timings = vec![
            QueryTiming {
                id: 0,
                label: "msbfs",
                arrival_ns: 1e9,
                start_ns: 1e9,
                finish_ns: 3e9,
                phases: 4,
                priority: Priority::Standard,
                admitted_as: Priority::Standard,
            },
            QueryTiming {
                id: 1,
                label: "bfs",
                arrival_ns: 2e9,
                start_ns: f64::NAN,
                finish_ns: f64::NAN,
                phases: 0,
                priority: Priority::Standard,
                admitted_as: Priority::Standard,
            },
        ];
        let flow = FlowReport {
            timings,
            makespan_ns: 3e9,
            counters: Counters::new(8),
            peak_concurrency: 1,
            rejected: vec![],
            shed: vec![1],
            peak_ctx_bytes: 0,
            preempted: vec![],
            parks: 0,
            resumes: 0,
            weights: crate::sim::flow::ShareWeights::flat(),
            events: 0,
        };
        let requests = vec![
            QueryRequest::new(Bfs { src: 1 }).at(0.0).with_deadline_ns(9e9),
            QueryRequest::new(Bfs { src: 2 }).at(1e9),
            QueryRequest::new(Bfs { src: 3 }).at(2e9),
        ];
        let m = machine();
        let rep = RunReport::from_flow_grouped("batched", &m, &requests, &[0, 0, 1], &flow);
        assert_eq!(rep.records.len(), 3, "one record per MEMBER, not per timing");
        // Member 0 arrived at 0 s, the fused query finished at 3 s: its
        // latency includes the 1 s batch-window wait.
        assert_eq!(rep.records[0].latency_s, 3.0);
        assert_eq!(rep.records[1].latency_s, 2.0);
        assert_eq!(rep.records[0].label, "bfs", "member label, not the fused msbfs");
        assert_eq!(rep.records[0].deadline_s, Some(9.0));
        assert_eq!(rep.records[0].arrival_s, 0.0);
        // The solo timing was shed: its one member records Shed.
        assert_eq!(rep.records[2].outcome, Outcome::Shed);
        assert_eq!(rep.completed(), 2);
        assert_eq!(rep.sheds(), 1);
        assert_eq!(rep.completed() + rep.sheds() + rep.rejections(), 3);
    }

    #[test]
    fn improvement_row_math() {
        let row = ImprovementRow {
            machine: "pathfinder-8".into(),
            queries: 128,
            concurrent_s: 226.0,
            sequential_s: 493.0,
        };
        // The paper's own 8-node numbers: 118% improvement, 2.18x.
        assert!((row.improvement_pct() - 118.0).abs() < 1.0);
        assert!((row.speedup() - 2.18).abs() < 0.01);
    }
}
