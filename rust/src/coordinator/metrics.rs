//! Run reports: per-query records, per-class quantiles (Table I),
//! improvement percentages (Fig. 4), and counter summaries.
//!
//! Reporting is class-generic: records carry the analysis label from the
//! request, and quantiles are available for any label that ran — a new
//! [`crate::alg::Analysis`] shows up in reports without any change here.

use crate::coordinator::request::{Priority, QueryRequest};
use crate::sim::counters::Counters;
use crate::sim::flow::FlowReport;
use crate::sim::machine::Machine;
use crate::util::stats::{improvement_pct, Quantiles};

/// One executed query's outcome.
#[derive(Debug, Clone)]
pub struct QueryRecord {
    pub id: usize,
    /// Analysis class label ("bfs", "cc", "sssp", ...).
    pub label: &'static str,
    /// Priority class the request carried.
    pub priority: Priority,
    /// Latency deadline (s from arrival), if the request had one.
    pub deadline_s: Option<f64>,
    /// End-to-end latency in seconds (arrival to completion), NaN if the
    /// query was rejected by admission control.
    pub latency_s: f64,
    /// Arrival time (s) within the run.
    pub arrival_s: f64,
    /// Completion time (s) within the run, NaN if rejected.
    pub finish_s: f64,
}

impl QueryRecord {
    pub fn rejected(&self) -> bool {
        self.latency_s.is_nan()
    }

    /// Completed but blew its deadline.
    pub fn missed_deadline(&self) -> bool {
        match self.deadline_s {
            Some(d) => !self.rejected() && self.latency_s > d,
            None => false,
        }
    }
}

/// Outcome of one coordinated run (one policy, one machine, one batch).
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Policy label ("sequential" / "concurrent" / "concurrent(cap=N)").
    pub policy: String,
    /// Machine preset name.
    pub machine: String,
    pub records: Vec<QueryRecord>,
    /// End-to-end time of the whole run (s).
    pub makespan_s: f64,
    /// Peak concurrency observed inside the engine.
    pub peak_concurrency: usize,
    /// Simulated hardware counters for the run.
    pub counters: Counters,
    /// Mean channel utilization over the run (the paper's thesis variable).
    pub mean_channel_utilization: f64,
}

impl RunReport {
    /// Build from a flow-engine report.
    pub fn from_flow(
        policy: impl Into<String>,
        machine: &Machine,
        requests: &[QueryRequest],
        flow: &FlowReport,
    ) -> Self {
        assert_eq!(requests.len(), flow.timings.len());
        let records = flow
            .timings
            .iter()
            .zip(requests)
            .map(|(t, req)| QueryRecord {
                id: t.id,
                label: req.label(),
                priority: req.priority,
                deadline_s: req.deadline_ns.map(|d| d * 1e-9),
                latency_s: t.latency_ns() * 1e-9,
                arrival_s: t.arrival_ns * 1e-9,
                finish_s: t.finish_ns * 1e-9,
            })
            .collect();
        let mean_channel_utilization = flow.counters.mean_channel_utilization(machine);
        RunReport {
            policy: policy.into(),
            machine: machine.cfg.name.clone(),
            records,
            makespan_s: flow.makespan_ns * 1e-9,
            peak_concurrency: flow.peak_concurrency,
            counters: flow.counters.clone(),
            mean_channel_utilization,
        }
    }

    /// Completed (non-rejected) query count.
    pub fn completed(&self) -> usize {
        self.records.iter().filter(|r| !r.rejected()).count()
    }

    /// Rejected query count.
    pub fn rejections(&self) -> usize {
        self.records.len() - self.completed()
    }

    /// Completed queries whose deadline was exceeded.
    pub fn deadline_misses(&self) -> usize {
        self.records.iter().filter(|r| r.missed_deadline()).count()
    }

    /// Distinct analysis labels in submission order of first appearance.
    pub fn labels(&self) -> Vec<&'static str> {
        crate::coordinator::request::distinct_labels(self.records.iter().map(|r| r.label))
    }

    /// Latencies (s) of completed queries, optionally filtered by label.
    pub fn latencies(&self, label: Option<&str>) -> Vec<f64> {
        self.records
            .iter()
            .filter(|r| !r.rejected())
            .filter(|r| label.is_none_or(|l| r.label == l))
            .map(|r| r.latency_s)
            .collect()
    }

    /// Quantile summary of per-query latency (s), optionally filtered by
    /// label. None if no completed query matches.
    pub fn latency_quantiles(&self, label: Option<&str>) -> Option<Quantiles> {
        let xs = self.latencies(label);
        if xs.is_empty() {
            None
        } else {
            Some(Quantiles::from_samples(&xs))
        }
    }

    /// Latency quantiles of every class that completed at least one query,
    /// in submission order of first appearance.
    pub fn per_class_quantiles(&self) -> Vec<(&'static str, Quantiles)> {
        self.labels()
            .into_iter()
            .filter_map(|l| self.latency_quantiles(Some(l)).map(|q| (l, q)))
            .collect()
    }

    /// Mean completed-query latency (s).
    pub fn mean_latency_s(&self) -> f64 {
        let xs = self.latencies(None);
        crate::util::stats::mean(&xs)
    }

    /// Completed queries per second of makespan.
    pub fn throughput_qps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            return 0.0;
        }
        self.completed() as f64 / self.makespan_s
    }
}

/// A paired sequential/concurrent comparison row (Fig. 3/4, Table II).
#[derive(Debug, Clone)]
pub struct ImprovementRow {
    pub machine: String,
    pub queries: usize,
    pub concurrent_s: f64,
    pub sequential_s: f64,
}

impl ImprovementRow {
    pub fn from_reports(conc: &RunReport, seq: &RunReport) -> Self {
        assert_eq!(conc.machine, seq.machine);
        ImprovementRow {
            machine: conc.machine.clone(),
            queries: conc.records.len(),
            concurrent_s: conc.makespan_s,
            sequential_s: seq.makespan_s,
        }
    }

    /// The paper's "% improvement of concurrent over sequential".
    pub fn improvement_pct(&self) -> f64 {
        improvement_pct(self.sequential_s, self.concurrent_s)
    }

    /// Speed-up factor (sequential / concurrent).
    pub fn speedup(&self) -> f64 {
        self.sequential_s / self.concurrent_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::{Bfs, Cc};
    use crate::config::machine::MachineConfig;
    use crate::sim::flow::QueryTiming;

    fn machine() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn flow_with(latencies_ns: &[f64]) -> (Vec<QueryRequest>, FlowReport) {
        let timings: Vec<QueryTiming> = latencies_ns
            .iter()
            .enumerate()
            .map(|(i, &l)| QueryTiming {
                id: i,
                label: "bfs",
                arrival_ns: 0.0,
                start_ns: 0.0,
                finish_ns: l,
                phases: 1,
            })
            .collect();
        let makespan = latencies_ns.iter().copied().fold(0.0, f64::max);
        let requests: Vec<QueryRequest> =
            latencies_ns.iter().map(|_| QueryRequest::new(Bfs { src: 0 })).collect();
        let flow = FlowReport {
            timings,
            makespan_ns: makespan,
            counters: Counters::new(8),
            peak_concurrency: latencies_ns.len(),
            rejected: vec![],
        };
        (requests, flow)
    }

    #[test]
    fn report_aggregates_latencies() {
        let (qs, flow) = flow_with(&[1e9, 2e9, 3e9, 4e9]);
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.completed(), 4);
        assert_eq!(rep.rejections(), 0);
        let q = rep.latency_quantiles(Some("bfs")).unwrap();
        assert_eq!(q.q0, 1.0);
        assert_eq!(q.q100, 4.0);
        assert_eq!(rep.makespan_s, 4.0);
        assert_eq!(rep.throughput_qps(), 1.0);
        assert!(rep.latency_quantiles(Some("cc")).is_none());
        assert_eq!(rep.labels(), vec!["bfs"]);
        let per_class = rep.per_class_quantiles();
        assert_eq!(per_class.len(), 1);
        assert_eq!(per_class[0].0, "bfs");
    }

    #[test]
    fn rejected_queries_excluded() {
        let (qs, mut flow) = flow_with(&[1e9, 2e9]);
        flow.timings[1].finish_ns = f64::NAN;
        flow.rejected = vec![1];
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.completed(), 1);
        assert_eq!(rep.rejections(), 1);
        assert_eq!(rep.latencies(None), vec![1.0]);
    }

    #[test]
    fn deadline_misses_counted() {
        let (mut qs, flow) = flow_with(&[1e9, 2e9, 3e9]);
        qs[0] = qs[0].clone().with_deadline_ns(5e8); // 0.5 s budget, 1 s latency
        qs[1] = qs[1].clone().with_deadline_ns(4e9); // met
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.deadline_misses(), 1);
        assert!(rep.records[0].missed_deadline());
        assert!(!rep.records[1].missed_deadline());
        assert!(!rep.records[2].missed_deadline()); // no deadline set
    }

    #[test]
    fn labels_preserve_first_appearance_order() {
        let (mut qs, flow) = flow_with(&[1e9, 2e9, 3e9]);
        qs[1] = QueryRequest::new(Cc);
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.labels(), vec!["bfs", "cc"]);
    }

    #[test]
    fn improvement_row_math() {
        let row = ImprovementRow {
            machine: "pathfinder-8".into(),
            queries: 128,
            concurrent_s: 226.0,
            sequential_s: 493.0,
        };
        // The paper's own 8-node numbers: 118% improvement, 2.18x.
        assert!((row.improvement_pct() - 118.0).abs() < 1.0);
        assert!((row.speedup() - 2.18).abs() < 0.01);
    }
}
