//! Run reports: per-query records, per-label quantiles (Table I),
//! improvement percentages (Fig. 4), and counter summaries.

use crate::alg::Query;
use crate::sim::counters::Counters;
use crate::sim::flow::FlowReport;
use crate::sim::machine::Machine;
use crate::util::stats::{improvement_pct, Quantiles};

/// One executed query's outcome.
#[derive(Debug, Clone)]
pub struct QueryRecord {
    pub id: usize,
    pub query: Query,
    /// End-to-end latency in seconds (arrival to completion), NaN if the
    /// query was rejected by admission control.
    pub latency_s: f64,
    /// Arrival time (s) within the run.
    pub arrival_s: f64,
    /// Completion time (s) within the run, NaN if rejected.
    pub finish_s: f64,
}

impl QueryRecord {
    pub fn rejected(&self) -> bool {
        self.latency_s.is_nan()
    }
}

/// Outcome of one coordinated run (one policy, one machine, one query set).
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Policy label ("sequential" / "concurrent" / "concurrent(cap=N)").
    pub policy: String,
    /// Machine preset name.
    pub machine: String,
    pub records: Vec<QueryRecord>,
    /// End-to-end time of the whole run (s).
    pub makespan_s: f64,
    /// Peak concurrency observed inside the engine.
    pub peak_concurrency: usize,
    /// Simulated hardware counters for the run.
    pub counters: Counters,
    /// Mean channel utilization over the run (the paper's thesis variable).
    pub mean_channel_utilization: f64,
}

impl RunReport {
    /// Build from a flow-engine report.
    pub fn from_flow(
        policy: impl Into<String>,
        machine: &Machine,
        queries: &[Query],
        flow: &FlowReport,
    ) -> Self {
        assert_eq!(queries.len(), flow.timings.len());
        let records = flow
            .timings
            .iter()
            .zip(queries)
            .map(|(t, q)| QueryRecord {
                id: t.id,
                query: *q,
                latency_s: t.latency_ns() * 1e-9,
                arrival_s: t.arrival_ns * 1e-9,
                finish_s: t.finish_ns * 1e-9,
            })
            .collect();
        let mean_channel_utilization = flow.counters.mean_channel_utilization(machine);
        RunReport {
            policy: policy.into(),
            machine: machine.cfg.name.clone(),
            records,
            makespan_s: flow.makespan_ns * 1e-9,
            peak_concurrency: flow.peak_concurrency,
            counters: flow.counters.clone(),
            mean_channel_utilization,
        }
    }

    /// Completed (non-rejected) query count.
    pub fn completed(&self) -> usize {
        self.records.iter().filter(|r| !r.rejected()).count()
    }

    /// Rejected query count.
    pub fn rejections(&self) -> usize {
        self.records.len() - self.completed()
    }

    /// Latencies (s) of completed queries, optionally filtered by label.
    pub fn latencies(&self, label: Option<&str>) -> Vec<f64> {
        self.records
            .iter()
            .filter(|r| !r.rejected())
            .filter(|r| label.is_none_or(|l| r.query.label() == l))
            .map(|r| r.latency_s)
            .collect()
    }

    /// Table-I style five-number summary of per-query latency (s).
    /// None if no completed query matches.
    pub fn latency_quantiles(&self, label: Option<&str>) -> Option<Quantiles> {
        let xs = self.latencies(label);
        if xs.is_empty() {
            None
        } else {
            Some(Quantiles::from_samples(&xs))
        }
    }

    /// Mean completed-query latency (s).
    pub fn mean_latency_s(&self) -> f64 {
        let xs = self.latencies(None);
        crate::util::stats::mean(&xs)
    }

    /// Completed queries per second of makespan.
    pub fn throughput_qps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            return 0.0;
        }
        self.completed() as f64 / self.makespan_s
    }
}

/// A paired sequential/concurrent comparison row (Fig. 3/4, Table II).
#[derive(Debug, Clone)]
pub struct ImprovementRow {
    pub machine: String,
    pub queries: usize,
    pub concurrent_s: f64,
    pub sequential_s: f64,
}

impl ImprovementRow {
    pub fn from_reports(conc: &RunReport, seq: &RunReport) -> Self {
        assert_eq!(conc.machine, seq.machine);
        ImprovementRow {
            machine: conc.machine.clone(),
            queries: conc.records.len(),
            concurrent_s: conc.makespan_s,
            sequential_s: seq.makespan_s,
        }
    }

    /// The paper's "% improvement of concurrent over sequential".
    pub fn improvement_pct(&self) -> f64 {
        improvement_pct(self.sequential_s, self.concurrent_s)
    }

    /// Speed-up factor (sequential / concurrent).
    pub fn speedup(&self) -> f64 {
        self.sequential_s / self.concurrent_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::sim::flow::QueryTiming;

    fn machine() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn flow_with(latencies_ns: &[f64]) -> (Vec<Query>, FlowReport) {
        let timings: Vec<QueryTiming> = latencies_ns
            .iter()
            .enumerate()
            .map(|(i, &l)| QueryTiming {
                id: i,
                label: "bfs",
                arrival_ns: 0.0,
                start_ns: 0.0,
                finish_ns: l,
                phases: 1,
            })
            .collect();
        let makespan = latencies_ns.iter().copied().fold(0.0, f64::max);
        let queries = vec![Query::Bfs { src: 0 }; latencies_ns.len()];
        let flow = FlowReport {
            timings,
            makespan_ns: makespan,
            counters: Counters::new(8),
            peak_concurrency: latencies_ns.len(),
            rejected: vec![],
        };
        (queries, flow)
    }

    #[test]
    fn report_aggregates_latencies() {
        let (qs, flow) = flow_with(&[1e9, 2e9, 3e9, 4e9]);
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.completed(), 4);
        assert_eq!(rep.rejections(), 0);
        let q = rep.latency_quantiles(Some("bfs")).unwrap();
        assert_eq!(q.q0, 1.0);
        assert_eq!(q.q100, 4.0);
        assert_eq!(rep.makespan_s, 4.0);
        assert_eq!(rep.throughput_qps(), 1.0);
        assert!(rep.latency_quantiles(Some("cc")).is_none());
    }

    #[test]
    fn rejected_queries_excluded() {
        let (qs, mut flow) = flow_with(&[1e9, 2e9]);
        flow.timings[1].finish_ns = f64::NAN;
        flow.rejected = vec![1];
        let m = machine();
        let rep = RunReport::from_flow("concurrent", &m, &qs, &flow);
        assert_eq!(rep.completed(), 1);
        assert_eq!(rep.rejections(), 1);
        assert_eq!(rep.latencies(None), vec![1.0]);
    }

    #[test]
    fn improvement_row_math() {
        let row = ImprovementRow {
            machine: "pathfinder-8".into(),
            queries: 128,
            concurrent_s: 226.0,
            sequential_s: 493.0,
        };
        // The paper's own 8-node numbers: 118% improvement, 2.18x.
        assert!((row.improvement_pct() - 118.0).abs() < 1.0);
        assert!((row.speedup() - 2.18).abs() < 0.01);
    }
}
