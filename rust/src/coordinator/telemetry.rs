//! Event-sourced telemetry: time-series and trace exports derived by
//! replaying a [`TraceBuffer`] (DESIGN.md §Observability).
//!
//! The engine emits *facts* ([`crate::sim::trace::TraceEvent`]); this
//! module derives the operator-facing views from them after the run:
//!
//! * **time-series** at a configurable sample interval — wait-queue
//!   depth per priority class, context-ledger bytes in flight, and
//!   per-chassis utilization (each open phase's rate x fractional
//!   demand, attributed over its node span);
//! * **`telemetry.json`** — event counts by type, the sampled series,
//!   and per-class p50/p95/p99 latency sections, machine-readable for
//!   CI tooling;
//! * **Chrome trace-event JSON** — openable in Perfetto or
//!   `chrome://tracing`: one process per query class with one track per
//!   query (nested phase spans inside the query span), a coordinator
//!   process for batch-fusion/epoch/routing instants, and counter
//!   tracks for the sampled series.
//!
//! Everything here is replay over an immutable event list: the engine
//! never computes a series itself, so adding a derived view costs the
//! hot loop nothing.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::sim::flow::Priority;
use crate::sim::trace::{TraceBuffer, TraceEvent};
use crate::util::json::Json;
use crate::util::stats::Quantiles;

/// How the replay samples its time-series.
#[derive(Debug, Clone, Copy)]
pub struct TelemetryConfig {
    /// Sample interval (simulated ns) for the derived series. `0.0`
    /// (the default) auto-picks span/256 — enough resolution to see
    /// ramps without exploding the artifact.
    pub sample_ns: f64,
    /// Nodes per chassis, for attributing phase demand spans to fleet
    /// members (a single machine is one chassis spanning every node).
    pub nodes_per_chassis: usize,
    /// Total machine nodes (defines the chassis count together with
    /// `nodes_per_chassis`).
    pub total_nodes: usize,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { sample_ns: 0.0, nodes_per_chassis: 8, total_nodes: 8 }
    }
}

impl TelemetryConfig {
    pub fn with_sample_ns(mut self, sample_ns: f64) -> Self {
        self.sample_ns = sample_ns;
        self
    }

    /// Chassis layout: `total` machine nodes in spans of `per_chassis`.
    pub fn with_chassis(mut self, per_chassis: usize, total: usize) -> Self {
        self.nodes_per_chassis = per_chassis.max(1);
        self.total_nodes = total.max(1);
        self
    }

    fn chassis_count(&self) -> usize {
        self.total_nodes.div_ceil(self.nodes_per_chassis)
    }
}

/// The derived telemetry of one traced run.
#[derive(Debug, Clone)]
pub struct Telemetry {
    /// Event counts by [`TraceEvent::kind`].
    pub event_counts: Vec<(&'static str, usize)>,
    /// Simulated span covered by the trace (ns).
    pub span_ns: f64,
    /// The sample interval actually used (ns).
    pub sample_ns: f64,
    /// Sample instants (ns).
    pub t_ns: Vec<f64>,
    /// Wait-queue depth per declared class at each sample instant.
    pub queue_depth: [Vec<usize>; 3],
    /// Context-ledger bytes in flight at each sample instant.
    pub ctx_bytes: Vec<u64>,
    /// Per-chassis utilization (sum of open phases' rate x fractional
    /// demand attributed to the chassis) at each sample instant.
    pub chassis_util: Vec<Vec<f64>>,
    /// Per-class completed latency quantiles (s), derived from
    /// arrival→finish event pairs; `None` when the class finished
    /// nothing.
    pub class_latency: [Option<Quantiles>; 3],
}

fn class_idx(p: Priority) -> usize {
    match p {
        Priority::Interactive => 0,
        Priority::Standard => 1,
        Priority::Batch => 2,
    }
}

const CLASS_NAMES: [&str; 3] = ["interactive", "standard", "batch"];

/// Replay `trace` into sampled time-series and summary sections.
pub fn analyze(trace: &TraceBuffer, cfg: &TelemetryConfig) -> Telemetry {
    // Chronological replay order; the engine emits in nondecreasing
    // time except for arrival stamps, so sort (stably — emission order
    // breaks ties, which keeps e.g. Admit-then-ReAnchor at one instant
    // in cause→effect order).
    let mut order: Vec<&TraceEvent> = trace.events.iter().collect();
    order.sort_by(|a, b| a.t_ns().total_cmp(&b.t_ns()));

    let span_ns = order.last().map(|ev| ev.t_ns()).unwrap_or(0.0).max(0.0);
    let sample_ns = if cfg.sample_ns > 0.0 {
        cfg.sample_ns
    } else {
        (span_ns / 256.0).max(1.0)
    };
    let chassis = cfg.chassis_count();

    // Live replay state.
    let mut queued: BTreeMap<usize, usize> = BTreeMap::new(); // id -> class
    let mut depth = [0usize; 3];
    let mut ctx_in_flight: u64 = 0;
    // id -> (node_offset, node_len, util_sum, rate) of its open phase.
    let mut open: BTreeMap<usize, (usize, usize, f64, f64)> = BTreeMap::new();
    let mut arrival: BTreeMap<usize, (f64, usize)> = BTreeMap::new(); // id -> (t, class)
    let mut lat: [Vec<f64>; 3] = [Vec::new(), Vec::new(), Vec::new()];

    let mut t_axis = Vec::new();
    let mut qd: [Vec<usize>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    let mut cb: Vec<u64> = Vec::new();
    let mut cu: Vec<Vec<f64>> = vec![Vec::new(); chassis];

    let mut next_sample = 0.0f64;
    let mut sample = |t_axis: &mut Vec<f64>,
                      qd: &mut [Vec<usize>; 3],
                      cb: &mut Vec<u64>,
                      cu: &mut Vec<Vec<f64>>,
                      t: f64,
                      depth: &[usize; 3],
                      ctx: u64,
                      open: &BTreeMap<usize, (usize, usize, f64, f64)>| {
        t_axis.push(t);
        for c in 0..3 {
            qd[c].push(depth[c]);
        }
        cb.push(ctx);
        for (ci, series) in cu.iter_mut().enumerate() {
            let lo = ci * cfg.nodes_per_chassis;
            let hi = ((ci + 1) * cfg.nodes_per_chassis).min(cfg.total_nodes);
            let mut u = 0.0;
            for &(off, len, util_sum, rate) in open.values() {
                if len == 0 {
                    continue;
                }
                let overlap = (off + len).min(hi).saturating_sub(off.max(lo));
                if overlap > 0 {
                    u += rate * util_sum * overlap as f64 / len as f64;
                }
            }
            series.push(u);
        }
    };

    for ev in &order {
        // Emit every sample instant that passed before this event.
        while next_sample <= ev.t_ns() {
            sample(
                &mut t_axis,
                &mut qd,
                &mut cb,
                &mut cu,
                next_sample,
                &depth,
                ctx_in_flight,
                &open,
            );
            next_sample += sample_ns;
        }
        match **ev {
            TraceEvent::Arrival { t_ns, id, class, .. } => {
                arrival.insert(id, (t_ns, class_idx(class)));
            }
            TraceEvent::QueueEnter { id, class, .. } => {
                if queued.insert(id, class_idx(class)).is_none() {
                    depth[class_idx(class)] += 1;
                }
            }
            TraceEvent::Admit { id, ctx_bytes, .. } => {
                if let Some(c) = queued.remove(&id) {
                    depth[c] -= 1;
                }
                ctx_in_flight += ctx_bytes;
            }
            TraceEvent::Reject { id, .. } | TraceEvent::Shed { id, .. } => {
                if let Some(c) = queued.remove(&id) {
                    depth[c] -= 1;
                }
            }
            TraceEvent::PhaseStart { id, node_offset, node_len, util_sum, .. } => {
                open.insert(id, (node_offset, node_len, util_sum, 1.0));
            }
            TraceEvent::PhaseEnd { id, .. } => {
                open.remove(&id);
            }
            TraceEvent::ReAnchor { id, rate, .. } => {
                if let Some(ph) = open.get_mut(&id) {
                    ph.3 = rate;
                }
            }
            TraceEvent::Finish { t_ns, id, ctx_bytes } => {
                ctx_in_flight = ctx_in_flight.saturating_sub(ctx_bytes);
                if let Some((t0, c)) = arrival.get(&id) {
                    lat[*c].push((t_ns - t0) * 1e-9);
                }
            }
            TraceEvent::Park { id, ctx_bytes, .. } => {
                ctx_in_flight = ctx_in_flight.saturating_sub(ctx_bytes);
                open.remove(&id);
            }
            TraceEvent::Resume { id: _, ctx_bytes, .. } => {
                ctx_in_flight += ctx_bytes;
            }
            TraceEvent::Solve { .. }
            | TraceEvent::BatchFuse { .. }
            | TraceEvent::EpochApply { .. }
            | TraceEvent::Compaction { .. }
            | TraceEvent::ShardRoute { .. } => {}
        }
    }
    // Close the series at the end of the span.
    if !order.is_empty() {
        sample(&mut t_axis, &mut qd, &mut cb, &mut cu, span_ns, &depth, ctx_in_flight, &open);
    }

    Telemetry {
        event_counts: trace.counts_by_kind(),
        span_ns,
        sample_ns,
        t_ns: t_axis,
        queue_depth: qd,
        ctx_bytes: cb,
        chassis_util: cu,
        class_latency: lat.map(|xs| Quantiles::try_from_samples(&xs)),
    }
}

impl Telemetry {
    /// The machine-readable `telemetry.json` document.
    pub fn to_json(&self) -> Json {
        let quant = |q: &Quantiles| {
            Json::obj(vec![
                ("p50", Json::Num(q.q50)),
                ("p95", Json::Num(q.q95)),
                ("p99", Json::Num(q.q99)),
            ])
        };
        Json::obj(vec![
            ("schema", Json::str("pfq-telemetry-v1")),
            (
                "event_counts",
                Json::Obj(
                    self.event_counts
                        .iter()
                        .map(|&(k, n)| (k.to_string(), Json::Num(n as f64)))
                        .collect(),
                ),
            ),
            ("span_ns", Json::Num(self.span_ns)),
            ("sample_ns", Json::Num(self.sample_ns)),
            (
                "series",
                Json::obj(vec![
                    ("t_ns", Json::arr(self.t_ns.iter().map(|&t| Json::Num(t)))),
                    (
                        "queue_depth",
                        Json::obj(
                            CLASS_NAMES
                                .iter()
                                .zip(&self.queue_depth)
                                .map(|(&name, xs)| {
                                    (name, Json::arr(xs.iter().map(|&d| Json::Num(d as f64))))
                                })
                                .collect(),
                        ),
                    ),
                    (
                        "ctx_bytes_in_flight",
                        Json::arr(self.ctx_bytes.iter().map(|&b| Json::Num(b as f64))),
                    ),
                    (
                        "chassis_utilization",
                        Json::Obj(
                            self.chassis_util
                                .iter()
                                .enumerate()
                                .map(|(ci, xs)| {
                                    (
                                        format!("chassis_{ci}"),
                                        Json::arr(xs.iter().map(|&u| Json::Num(u))),
                                    )
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "class_latency_s",
                Json::obj(
                    CLASS_NAMES
                        .iter()
                        .zip(&self.class_latency)
                        .map(|(&name, q)| {
                            (name, q.as_ref().map(&quant).unwrap_or(Json::Null))
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

// Chrome trace-event constants: process ids group tracks in Perfetto.
const PID_CLASS_BASE: usize = 1; // 1..=3: one process per query class
const PID_COORD: usize = 4;
const PID_COUNTERS: usize = 5;

/// Render the event stream as Chrome trace-event JSON
/// (<https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>,
/// the format Perfetto and `chrome://tracing` open).
///
/// Layout: one *process* per query class, one *thread* (track) per
/// query id; the query's admitted lifetime is a `B`/`E` span with its
/// phases as nested spans, and queue/shed/park/resume moments are
/// instants on the same track. Coordinator events (batch fusion, epoch
/// apply, compaction, shard routing) land on a `coordinator` process;
/// the sampled series from [`analyze`] are emitted as `C` counter
/// events. Timestamps are microseconds (the format's unit), sorted
/// nondecreasing; the B/E nesting is balanced per track by
/// construction (a park never leaves a phase span open — phases close
/// at the checkpoint before the park).
pub fn chrome_trace(trace: &TraceBuffer, telemetry: &Telemetry) -> Json {
    let mut order: Vec<&TraceEvent> = trace.events.iter().collect();
    order.sort_by(|a, b| a.t_ns().total_cmp(&b.t_ns()));

    // id -> label (from arrival events) for span names.
    let mut labels: BTreeMap<usize, &'static str> = BTreeMap::new();
    for ev in &order {
        if let TraceEvent::Arrival { id, label, .. } = **ev {
            labels.insert(id, label);
        }
    }
    // id -> class process (declared at arrival; fall back to standard).
    let pid_of = |class: Priority| PID_CLASS_BASE + class_idx(class);

    let us = |t_ns: f64| Json::Num(t_ns / 1000.0);
    let mut events: Vec<Json> = Vec::new();

    // Process-name metadata rows.
    for (pid, name) in [
        (PID_CLASS_BASE, "queries: interactive"),
        (PID_CLASS_BASE + 1, "queries: standard"),
        (PID_CLASS_BASE + 2, "queries: batch"),
        (PID_COORD, "coordinator"),
        (PID_COUNTERS, "counters"),
    ] {
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(0.0)),
            ("args", Json::obj(vec![("name", Json::str(name))])),
        ]));
    }

    let span = |name: String, ph: &str, t_ns: f64, pid: usize, id: usize, args: Json| {
        Json::obj(vec![
            ("name", Json::str(name)),
            ("ph", Json::str(ph)),
            ("ts", us(t_ns)),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(id as f64)),
            ("args", args),
        ])
    };
    let instant = |name: String, t_ns: f64, pid: usize, id: usize, args: Json| {
        Json::obj(vec![
            ("name", Json::str(name)),
            ("ph", Json::str("i")),
            ("s", Json::str("t")),
            ("ts", us(t_ns)),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(id as f64)),
            ("args", args),
        ])
    };

    // id -> class pid while admitted, so phase/park/finish rows land on
    // the same track the admit opened even though those events carry no
    // class.
    let mut track: BTreeMap<usize, usize> = BTreeMap::new();
    for ev in &order {
        match **ev {
            TraceEvent::Arrival { t_ns, id, label, class } => {
                track.insert(id, pid_of(class));
                events.push(instant(
                    format!("arrive {label}"),
                    t_ns,
                    pid_of(class),
                    id,
                    Json::obj(vec![]),
                ));
            }
            TraceEvent::QueueEnter { t_ns, id, class, waiting } => {
                events.push(instant(
                    "queue".to_string(),
                    t_ns,
                    pid_of(class),
                    id,
                    Json::obj(vec![("waiting", Json::Num(waiting as f64))]),
                ));
            }
            TraceEvent::Admit { t_ns, id, class, admitted_as, wait_ns, ctx_bytes } => {
                let label = labels.get(&id).copied().unwrap_or("query");
                events.push(span(
                    format!("{label} #{id}"),
                    "B",
                    t_ns,
                    pid_of(class),
                    id,
                    Json::obj(vec![
                        ("admitted_as", Json::str(format!("{admitted_as}"))),
                        ("wait_ns", Json::Num(wait_ns)),
                        ("ctx_bytes", Json::Num(ctx_bytes as f64)),
                    ]),
                ));
            }
            TraceEvent::Reject { t_ns, id, class, oversized } => {
                events.push(instant(
                    "reject".to_string(),
                    t_ns,
                    pid_of(class),
                    id,
                    Json::obj(vec![("oversized", Json::Bool(oversized))]),
                ));
            }
            TraceEvent::Shed { t_ns, id, class, expired } => {
                events.push(instant(
                    "shed".to_string(),
                    t_ns,
                    pid_of(class),
                    id,
                    Json::obj(vec![("deadline_expired", Json::Bool(expired))]),
                ));
            }
            TraceEvent::PhaseStart { t_ns, id, phase, solo_ns, util_sum, .. } => {
                let pid = track.get(&id).copied().unwrap_or(PID_CLASS_BASE + 1);
                events.push(span(
                    format!("phase {phase}"),
                    "B",
                    t_ns,
                    pid,
                    id,
                    Json::obj(vec![
                        ("solo_ns", Json::Num(solo_ns)),
                        ("util_sum", Json::Num(util_sum)),
                    ]),
                ));
            }
            TraceEvent::PhaseEnd { t_ns, id, phase } => {
                let pid = track.get(&id).copied().unwrap_or(PID_CLASS_BASE + 1);
                events.push(span(format!("phase {phase}"), "E", t_ns, pid, id, Json::obj(vec![])));
            }
            TraceEvent::Finish { t_ns, id, .. } => {
                let pid = track.get(&id).copied().unwrap_or(PID_CLASS_BASE + 1);
                let label = labels.get(&id).copied().unwrap_or("query");
                events.push(span(
                    format!("{label} #{id}"),
                    "E",
                    t_ns,
                    pid,
                    id,
                    Json::obj(vec![]),
                ));
            }
            TraceEvent::Park { t_ns, id, next_phase, .. } => {
                let pid = track.get(&id).copied().unwrap_or(PID_CLASS_BASE + 1);
                events.push(instant(
                    "park".to_string(),
                    t_ns,
                    pid,
                    id,
                    Json::obj(vec![("next_phase", Json::Num(next_phase as f64))]),
                ));
            }
            TraceEvent::Resume { t_ns, id, phase, .. } => {
                let pid = track.get(&id).copied().unwrap_or(PID_CLASS_BASE + 1);
                events.push(instant(
                    "resume".to_string(),
                    t_ns,
                    pid,
                    id,
                    Json::obj(vec![("phase", Json::Num(phase as f64))]),
                ));
            }
            TraceEvent::Solve { t_ns, members, resources } => {
                events.push(instant(
                    "solve".to_string(),
                    t_ns,
                    PID_COORD,
                    0,
                    Json::obj(vec![
                        ("members", Json::Num(members as f64)),
                        ("resources", Json::Num(resources as f64)),
                    ]),
                ));
            }
            TraceEvent::ReAnchor { t_ns, id, rate } => {
                let pid = track.get(&id).copied().unwrap_or(PID_CLASS_BASE + 1);
                events.push(instant(
                    "re-anchor".to_string(),
                    t_ns,
                    pid,
                    id,
                    Json::obj(vec![("rate", Json::Num(rate))]),
                ));
            }
            TraceEvent::BatchFuse { t_ns, id, width, label } => {
                events.push(instant(
                    format!("fuse {label}"),
                    t_ns,
                    PID_COORD,
                    1,
                    Json::obj(vec![
                        ("fused_id", Json::Num(id as f64)),
                        ("width", Json::Num(width as f64)),
                    ]),
                ));
            }
            TraceEvent::EpochApply { t_ns, epoch, updates } => {
                events.push(instant(
                    format!("epoch {epoch}"),
                    t_ns,
                    PID_COORD,
                    2,
                    Json::obj(vec![("updates", Json::Num(updates as f64))]),
                ));
            }
            TraceEvent::Compaction { t_ns, epoch, drained } => {
                events.push(instant(
                    format!("compact@{epoch}"),
                    t_ns,
                    PID_COORD,
                    2,
                    Json::obj(vec![("overlays_drained", Json::Num(drained as f64))]),
                ));
            }
            TraceEvent::ShardRoute { t_ns, id, shard, replica } => {
                events.push(instant(
                    format!("route shard {shard}"),
                    t_ns,
                    PID_COORD,
                    3,
                    Json::obj(vec![
                        ("query", Json::Num(id as f64)),
                        ("replica", Json::Num(replica as f64)),
                    ]),
                ));
            }
        }
    }

    // Counter tracks from the sampled series.
    for (si, &t) in telemetry.t_ns.iter().enumerate() {
        events.push(Json::obj(vec![
            ("name", Json::str("queue depth")),
            ("ph", Json::str("C")),
            ("ts", us(t)),
            ("pid", Json::Num(PID_COUNTERS as f64)),
            ("tid", Json::Num(0.0)),
            (
                "args",
                Json::obj(
                    CLASS_NAMES
                        .iter()
                        .zip(&telemetry.queue_depth)
                        .map(|(&name, xs)| (name, Json::Num(xs[si] as f64)))
                        .collect(),
                ),
            ),
        ]));
        events.push(Json::obj(vec![
            ("name", Json::str("ctx bytes in flight")),
            ("ph", Json::str("C")),
            ("ts", us(t)),
            ("pid", Json::Num(PID_COUNTERS as f64)),
            ("tid", Json::Num(0.0)),
            ("args", Json::obj(vec![("bytes", Json::Num(telemetry.ctx_bytes[si] as f64))])),
        ]));
        events.push(Json::obj(vec![
            ("name", Json::str("chassis utilization")),
            ("ph", Json::str("C")),
            ("ts", us(t)),
            ("pid", Json::Num(PID_COUNTERS as f64)),
            ("tid", Json::Num(0.0)),
            (
                "args",
                Json::Obj(
                    telemetry
                        .chassis_util
                        .iter()
                        .enumerate()
                        .map(|(ci, xs)| (format!("chassis_{ci}"), Json::Num(xs[si])))
                        .collect(),
                ),
            ),
        ]));
    }

    // Chrome requires nondecreasing only per importer buffer, but the
    // CI validator pins a globally sorted artifact: stable-sort by ts
    // (metadata rows have no ts and sort first).
    events.sort_by(|a, b| {
        let ts = |e: &Json| e.get("ts").ok().and_then(|t| t.as_f64().ok()).unwrap_or(-1.0);
        ts(a).total_cmp(&ts(b))
    });

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ns")),
    ])
}

/// Analyze `trace` and write both artifacts: Chrome trace JSON at
/// `chrome_path`, and the machine-readable telemetry next to it at
/// `<stem>.telemetry.json`. Returns the derived [`Telemetry`].
pub fn export(
    trace: &TraceBuffer,
    cfg: &TelemetryConfig,
    chrome_path: &std::path::Path,
) -> Result<Telemetry> {
    let telemetry = analyze(trace, cfg);
    chrome_trace(trace, &telemetry).write_file(chrome_path)?;
    telemetry.to_json().write_file(&telemetry_path(chrome_path))?;
    Ok(telemetry)
}

/// The sibling `telemetry.json` path for a Chrome-trace path:
/// `out.json` → `out.telemetry.json`.
pub fn telemetry_path(chrome_path: &std::path::Path) -> std::path::PathBuf {
    let stem = chrome_path.file_stem().and_then(|s| s.to_str()).unwrap_or("trace");
    chrome_path.with_file_name(format!("{stem}.telemetry.json"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::trace::TraceSink;

    fn demo_trace() -> TraceBuffer {
        let mut b = TraceBuffer::new();
        // Query 7 (interactive): arrives, admits, two phases, finishes.
        b.emit(TraceEvent::Arrival {
            t_ns: 0.0,
            id: 7,
            label: "bfs",
            class: Priority::Interactive,
        });
        b.emit(TraceEvent::Admit {
            t_ns: 0.0,
            id: 7,
            class: Priority::Interactive,
            admitted_as: Priority::Interactive,
            wait_ns: 0.0,
            ctx_bytes: 100,
        });
        b.emit(TraceEvent::PhaseStart {
            t_ns: 0.0,
            id: 7,
            phase: 0,
            solo_ns: 50.0,
            node_offset: 0,
            node_len: 8,
            util_sum: 0.5,
        });
        b.emit(TraceEvent::ReAnchor { t_ns: 0.0, id: 7, rate: 0.8 });
        b.emit(TraceEvent::PhaseEnd { t_ns: 60.0, id: 7, phase: 0 });
        b.emit(TraceEvent::PhaseStart {
            t_ns: 60.0,
            id: 7,
            phase: 1,
            solo_ns: 40.0,
            node_offset: 0,
            node_len: 8,
            util_sum: 0.25,
        });
        b.emit(TraceEvent::PhaseEnd { t_ns: 100.0, id: 7, phase: 1 });
        b.emit(TraceEvent::Finish { t_ns: 100.0, id: 7, ctx_bytes: 100 });
        // Query 9 (batch): queues, sheds.
        b.emit(TraceEvent::Arrival { t_ns: 10.0, id: 9, label: "cc", class: Priority::Batch });
        b.emit(TraceEvent::QueueEnter { t_ns: 10.0, id: 9, class: Priority::Batch, waiting: 1 });
        b.emit(TraceEvent::Shed { t_ns: 80.0, id: 9, class: Priority::Batch, expired: true });
        b
    }

    #[test]
    fn replay_derives_queue_depth_and_ctx_series() {
        let tel = analyze(&demo_trace(), &TelemetryConfig::default().with_sample_ns(25.0));
        assert_eq!(tel.span_ns, 100.0);
        // Samples at 0,25,50,75,100 plus the closing sample.
        assert_eq!(tel.t_ns.len(), 6);
        // Batch queue depth: 0 at t=0, 1 while 9 waits (25..=75), 0 after.
        assert_eq!(tel.queue_depth[2], vec![0, 1, 1, 1, 0, 0]);
        // Samples fire *before* same-instant events: the t=0 sample
        // precedes the admit and the closing sample follows the finish,
        // so ctx bytes are 0 at both ends and 100 in between.
        assert_eq!(tel.ctx_bytes, vec![0, 100, 100, 100, 100, 0]);
        // Utilization: phase 0 at rate 0.8 x 0.5 = 0.4 on chassis 0.
        assert!((tel.chassis_util[0][1] - 0.4).abs() < 1e-12);
        // Phase 1 runs at rate 1.0 (no re-anchor): 0.25.
        assert!((tel.chassis_util[0][3] - 0.25).abs() < 1e-12);
        // One interactive completion, latency 100 ns.
        let q = tel.class_latency[0].as_ref().unwrap();
        assert!((q.q50 - 1e-7).abs() < 1e-18);
        assert!(tel.class_latency[2].is_none(), "shed batch query has no latency");
        assert_eq!(
            tel.event_counts,
            vec![
                ("admit", 1),
                ("arrival", 2),
                ("finish", 1),
                ("phase_end", 2),
                ("phase_start", 2),
                ("queue_enter", 1),
                ("re_anchor", 1),
                ("shed", 1),
            ]
        );
    }

    #[test]
    fn chrome_trace_spans_balance_and_sort() {
        let trace = demo_trace();
        let tel = analyze(&trace, &TelemetryConfig::default().with_sample_ns(50.0));
        let doc = chrome_trace(&trace, &tel);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // Balanced B/E per (pid, tid), LIFO.
        let mut stacks: BTreeMap<(u64, u64), Vec<String>> = BTreeMap::new();
        let mut last_ts = -1.0f64;
        for ev in events {
            let ph = ev.str_of("ph").unwrap();
            if let Ok(ts) = ev.get("ts").and_then(|t| t.as_f64()) {
                assert!(ts >= last_ts, "timestamps must be nondecreasing");
                last_ts = ts;
            }
            if ph == "B" || ph == "E" {
                let key = (ev.get("pid").unwrap().as_u64().unwrap(),
                           ev.get("tid").unwrap().as_u64().unwrap());
                let name = ev.str_of("name").unwrap();
                let stack = stacks.entry(key).or_default();
                if ph == "B" {
                    stack.push(name);
                } else {
                    assert_eq!(stack.pop().as_deref(), Some(name.as_str()), "LIFO nesting");
                }
            }
        }
        assert!(stacks.values().all(|s| s.is_empty()), "every span closed");
        // Counter rows made it in.
        assert!(events.iter().any(|e| e.str_of("ph").is_ok_and(|p| p == "C")));
        // The shed query shows as an instant, not a span.
        assert!(events
            .iter()
            .any(|e| e.str_of("name").is_ok_and(|n| n == "shed")));
    }

    #[test]
    fn telemetry_json_shape() {
        let trace = demo_trace();
        let tel = analyze(&trace, &TelemetryConfig::default());
        let doc = tel.to_json();
        assert_eq!(doc.str_of("schema").unwrap(), "pfq-telemetry-v1");
        let series = doc.get("series").unwrap();
        assert!(series.get("queue_depth").unwrap().get("interactive").is_ok());
        assert!(series.get("ctx_bytes_in_flight").unwrap().as_arr().is_ok());
        assert!(series.get("chassis_utilization").unwrap().get("chassis_0").is_ok());
        let lat = doc.get("class_latency_s").unwrap();
        assert!(lat.get("interactive").unwrap().get("p99").is_ok());
        assert!(matches!(lat.get("batch").unwrap(), Json::Null));
    }

    #[test]
    fn telemetry_path_sibling_naming() {
        assert_eq!(
            telemetry_path(std::path::Path::new("/tmp/out.json")),
            std::path::PathBuf::from("/tmp/out.telemetry.json")
        );
    }
}
