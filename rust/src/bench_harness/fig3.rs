//! Figure 3: total time (s) for concurrent vs sequential BFS queries, on
//! the 8-node and 32-node machines, across the query-count sweep.

use anyhow::Result;

use crate::coordinator::{ImprovementRow, Policy};
use crate::util::format::{fmt_s, TextTable};

use super::context::Harness;

/// The Fig. 3 dataset: one [`ImprovementRow`] per (machine, query count).
#[derive(Debug, Clone)]
pub struct Fig3Data {
    pub rows: Vec<ImprovementRow>,
}

impl Fig3Data {
    /// Rows of one machine.
    pub fn machine(&self, name: &str) -> Vec<&ImprovementRow> {
        self.rows.iter().filter(|r| r.machine == name).collect()
    }

    /// Render the paper-shaped series table.
    pub fn table(&self) -> TextTable {
        let mut t = TextTable::new(vec![
            "machine",
            "queries",
            "concurrent (s)",
            "sequential (s)",
            "speedup",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.machine.clone(),
                r.queries.to_string(),
                fmt_s(r.concurrent_s),
                fmt_s(r.sequential_s),
                format!("{:.2}x", r.speedup()),
            ]);
        }
        t
    }

    /// Check the paper's headline observation: "times increase linearly
    /// with the number of BFS queries in all cases". Returns the worst
    /// R^2-style deviation of per-query time across counts >= `min_q`.
    pub fn linearity_deviation(&self, machine: &str, min_q: usize) -> f64 {
        let rows: Vec<&ImprovementRow> = self
            .machine(machine)
            .into_iter()
            .filter(|r| r.queries >= min_q)
            .collect();
        if rows.len() < 2 {
            return 0.0;
        }
        let per_query: Vec<f64> =
            rows.iter().map(|r| r.concurrent_s / r.queries as f64).collect();
        let mean = crate::util::stats::mean(&per_query);
        per_query
            .iter()
            .map(|x| (x - mean).abs() / mean)
            .fold(0.0, f64::max)
    }
}

/// Run the Fig. 3 sweep.
pub fn run(h: &Harness) -> Result<Fig3Data> {
    let mut rows = Vec::new();
    for bench in h.benches() {
        let counts = bench.counts(&h.cfg.workload.query_counts);
        for k in counts {
            let conc = bench.coordinator.run_specs(
                &bench.queries[..k],
                &bench.specs[..k],
                Policy::Concurrent,
            )?;
            let seq = bench.coordinator.run_specs(
                &bench.queries[..k],
                &bench.specs[..k],
                Policy::Sequential,
            )?;
            rows.push(ImprovementRow::from_reports(&conc, &seq));
        }
    }
    Ok(Fig3Data { rows })
}

/// Run, print, save CSV.
pub fn report(h: &Harness) -> Result<Fig3Data> {
    let data = run(h)?;
    println!("== Figure 3: concurrent vs sequential BFS (total time) ==");
    println!("{}", data.table().render());
    let p = h.save_csv(&data.table(), "fig3_bfs_conc_vs_seq")?;
    println!("csv: {p}");
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::ExperimentConfig;
    use crate::config::workload::GraphConfig;

    fn h() -> Harness {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.graph = GraphConfig::with_scale(11);
        cfg.workload.query_counts = vec![2, 8, 16];
        cfg.workload.mixes.clear();
        Harness::new(cfg).unwrap()
    }

    #[test]
    fn produces_rows_for_both_machines() {
        let data = run(&h()).unwrap();
        assert_eq!(data.rows.len(), 6);
        assert_eq!(data.machine("pathfinder-8").len(), 3);
        assert_eq!(data.machine("pathfinder-32").len(), 3);
    }

    #[test]
    fn concurrent_wins_at_every_point() {
        let data = run(&h()).unwrap();
        for r in &data.rows {
            if r.queries >= 8 {
                assert!(
                    r.speedup() > 1.5,
                    "{} q={}: speedup {:.2}",
                    r.machine,
                    r.queries,
                    r.speedup()
                );
            }
        }
    }

    #[test]
    fn times_linear_in_query_count() {
        let data = run(&h()).unwrap();
        // Per-query concurrent time stable to within 40% across counts
        // (small-scale graphs are noisier than the paper's scale 25).
        assert!(data.linearity_deviation("pathfinder-8", 8) < 0.4);
    }
}
