//! Figure 4: improvement (%) of concurrent over sequential queries, by
//! query count and machine — the paper's headline chart (>2x on the
//! single chassis, 81–97 % on the degraded four-chassis system).

use anyhow::Result;

use crate::util::format::{fmt_pct, TextTable};

use super::context::Harness;
use super::fig3::{self, Fig3Data};

/// Fig. 4 is a direct re-expression of the Fig. 3 dataset.
#[derive(Debug, Clone)]
pub struct Fig4Data {
    pub fig3: Fig3Data,
}

impl Fig4Data {
    pub fn table(&self) -> TextTable {
        let mut t = TextTable::new(vec!["machine", "queries", "improvement (%)"]);
        for r in &self.fig3.rows {
            t.row(vec![
                r.machine.clone(),
                r.queries.to_string(),
                fmt_pct(r.improvement_pct()),
            ]);
        }
        t
    }

    /// Improvement range (min, max) over counts >= `min_q` for a machine.
    pub fn improvement_range(&self, machine: &str, min_q: usize) -> Option<(f64, f64)> {
        let vals: Vec<f64> = self
            .fig3
            .machine(machine)
            .into_iter()
            .filter(|r| r.queries >= min_q)
            .map(|r| r.improvement_pct())
            .collect();
        if vals.is_empty() {
            return None;
        }
        Some((
            vals.iter().copied().fold(f64::INFINITY, f64::min),
            vals.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        ))
    }
}

pub fn run(h: &Harness) -> Result<Fig4Data> {
    Ok(Fig4Data { fig3: fig3::run(h)? })
}

pub fn report(h: &Harness) -> Result<Fig4Data> {
    let data = run(h)?;
    println!("== Figure 4: improvement (%) of concurrent over sequential ==");
    println!("{}", data.table().render());
    if let Some((lo, hi)) = data.improvement_range("pathfinder-8", 8) {
        println!("pathfinder-8 range:  {:.0}%..{:.0}%  (paper: >100%)", lo, hi);
    }
    if let Some((lo, hi)) = data.improvement_range("pathfinder-32", 8) {
        println!("pathfinder-32 range: {:.0}%..{:.0}%  (paper: 81%..97%)", lo, hi);
    }
    let p = h.save_csv(&data.table(), "fig4_improvement")?;
    println!("csv: {p}");
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::ExperimentConfig;
    use crate::config::workload::GraphConfig;

    #[test]
    fn paper_shape_holds_at_small_scale() {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.graph = GraphConfig::with_scale(12);
        cfg.workload.query_counts = vec![8, 32];
        cfg.workload.mixes.clear();
        let h = Harness::new(cfg).unwrap();
        let d = run(&h).unwrap();
        let (lo8, _) = d.improvement_range("pathfinder-8", 8).unwrap();
        let (lo32, _) = d.improvement_range("pathfinder-32", 8).unwrap();
        // 8-node beats 2x (the paper's "consistently greater than 2x").
        assert!(lo8 > 100.0, "8-node improvement {lo8:.0}%");
        assert!(lo32 > 50.0, "32-node improvement {lo32:.0}%");
        // The full paper-shape band (8-node above 32-node, 32-node in
        // 81-97%) needs scale >= 14 and is asserted in e2e_tests.rs.
    }
}
