//! §IV-B scaling anecdotes:
//!
//! * the 128-query 8→32-node speed-ups (paper: 2.69x concurrent, 3.24x
//!   sequential — decidedly sub-linear on the degraded machine);
//! * the 256-queries-on-8-nodes thread-context exhaustion, reproduced as
//!   an admission failure plus the graceful queued alternative.

use anyhow::Result;

use crate::coordinator::Policy;
use crate::sim::flow::OnFull;
use crate::util::format::{fmt_s, TextTable};

use super::context::Harness;

#[derive(Debug, Clone)]
pub struct ScalingData {
    pub queries: usize,
    /// (machine, concurrent s, sequential s).
    pub rows: Vec<(String, f64, f64)>,
    /// 8→32 node speed-ups (concurrent, sequential), if both machines ran.
    pub speedups: Option<(f64, f64)>,
    /// The context-exhaustion demo: (attempted queries, capacity,
    /// error text, queued-makespan s).
    pub exhaustion: Option<(usize, usize, String, f64)>,
}

impl ScalingData {
    pub fn table(&self) -> TextTable {
        let mut t = TextTable::new(vec!["machine", "concurrent (s)", "sequential (s)"]);
        for (m, c, s) in &self.rows {
            t.row(vec![m.clone(), fmt_s(*c), fmt_s(*s)]);
        }
        t
    }
}

pub fn run(h: &Harness, queries: usize) -> Result<ScalingData> {
    let mut rows = Vec::new();
    for bench in h.benches() {
        let k = queries.min(bench.specs.len()).min(bench.coordinator.capacity());
        if k < queries {
            continue;
        }
        let conc = bench.coordinator.run_specs(
            &bench.queries[..k],
            &bench.specs[..k],
            Policy::Concurrent,
        )?;
        let seq = bench.coordinator.run_specs(
            &bench.queries[..k],
            &bench.specs[..k],
            Policy::Sequential,
        )?;
        rows.push((bench.name().to_string(), conc.makespan_s, seq.makespan_s));
    }
    let speedups = (rows.len() >= 2).then(|| (rows[0].1 / rows[1].1, rows[0].2 / rows[1].2));

    // Context exhaustion on the smallest machine: submit capacity+1
    // queries (the paper hit this wall at 256 on 8 nodes).
    let exhaustion = match h.cfg.machines.iter().min_by_key(|m| m.nodes) {
        Some(mcfg) => {
            let machine = crate::sim::machine::Machine::new(mcfg.clone());
            let coord = crate::coordinator::Coordinator::new(&h.g, machine);
            let cap = coord.capacity();
            let attempt = cap + 1;
            let qs = crate::coordinator::planner::bfs_queries(
                &h.g,
                attempt,
                h.cfg.workload.source_seed,
            );
            let specs = coord.prepare(&qs);
            let err = coord
                .run_specs(&qs, &specs, Policy::Concurrent)
                .expect_err("over-capacity run must fail")
                .to_string();
            let queued = coord.run_specs(&qs, &specs, Policy::admitted(OnFull::Queue))?;
            Some((attempt, cap, err, queued.makespan_s))
        }
        None => None,
    };

    Ok(ScalingData { queries, rows, speedups, exhaustion })
}

pub fn report(h: &Harness, queries: usize) -> Result<ScalingData> {
    let data = run(h, queries)?;
    println!("== §IV-B scaling: {} BFS queries across machines ==", data.queries);
    println!("{}", data.table().render());
    if let Some((conc, seq)) = data.speedups {
        println!(
            "8->32-node speed-up: {conc:.2}x concurrent, {seq:.2}x sequential \
             (paper: 2.69x / 3.24x — sub-linear on the degraded machine)"
        );
    }
    if let Some((attempt, cap, err, queued_s)) = &data.exhaustion {
        println!();
        println!("context exhaustion: {attempt} concurrent queries vs capacity {cap}:");
        println!("  unadmitted: ERROR — {err}");
        println!("  with admission(queue): completes in {}", fmt_s(*queued_s));
    }
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::ExperimentConfig;
    use crate::config::workload::GraphConfig;

    #[test]
    fn sublinear_scaling_and_exhaustion() {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.graph = GraphConfig::with_scale(11);
        cfg.workload.query_counts = vec![32];
        cfg.workload.mixes.clear();
        // Shrink 8-node capacity so the exhaustion demo triggers quickly.
        cfg.machines[0].ctx_mem_per_node_bytes = 32 << 20; // capacity 16
        let h = Harness::new(cfg).unwrap();
        let d = run(&h, 16).unwrap();
        assert_eq!(d.rows.len(), 2);
        let (conc_sp, seq_sp) = d.speedups.unwrap();
        // More nodes help, but far less than 4x on the degraded machine.
        // (16 queries at scale 11 barely load the 32-node box; the paper's
        // 2.69x/3.24x point is asserted at scale >= 14 in e2e_tests.rs.)
        assert!(conc_sp > 1.05 && conc_sp < 4.0, "conc {conc_sp}");
        assert!(seq_sp > 1.05 && seq_sp < 4.2, "seq {seq_sp}");
        let (attempt, cap, err, queued_s) = d.exhaustion.unwrap();
        assert_eq!(cap, 16);
        assert_eq!(attempt, 17);
        assert!(err.contains("thread-context memory"));
        assert!(queued_s > 0.0);
    }
}
