//! Table II: concurrent vs sequential times for BFS+CC mixes, with the
//! paper's % improvement column.
//!
//! The sequential arm is the paper's: "all the breadth-first searches
//! followed by all the connected components evaluations" (§IV-C). Each mix
//! runs on the smallest configured machine whose thread-context capacity
//! fits it — reproducing the paper's assignment (the 170-query mixes on
//! 8 nodes, the 700-query mixes on the full Pathfinder).

use anyhow::Result;

use crate::config::workload::MixPoint;
use crate::coordinator::{planner, Coordinator, Policy};
use crate::sim::machine::Machine;
use crate::util::format::{fmt_pct, fmt_s, TextTable};
use crate::util::stats::improvement_pct;

use super::context::Harness;

#[derive(Debug, Clone)]
pub struct Table2Row {
    pub machine: String,
    pub mix: MixPoint,
    pub concurrent_s: f64,
    pub sequential_s: f64,
}

impl Table2Row {
    pub fn improvement_pct(&self) -> f64 {
        improvement_pct(self.sequential_s, self.concurrent_s)
    }
}

#[derive(Debug, Clone)]
pub struct Table2Data {
    pub rows: Vec<Table2Row>,
}

impl Table2Data {
    pub fn table(&self) -> TextTable {
        let mut t = TextTable::new(vec![
            "machine",
            "# BFS",
            "# CC",
            "conc. time (s)",
            "seq. time (s)",
            "% impr.",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.machine.clone(),
                r.mix.bfs.to_string(),
                r.mix.cc.to_string(),
                fmt_s(r.concurrent_s),
                fmt_s(r.sequential_s),
                fmt_pct(r.improvement_pct()),
            ]);
        }
        t
    }
}

pub fn run(h: &Harness) -> Result<Table2Data> {
    let mut rows = Vec::new();
    for mix in &h.cfg.workload.mixes {
        // Smallest machine that can hold the whole mix concurrently.
        let Some(mcfg) = h
            .cfg
            .machines
            .iter()
            .filter(|m| m.max_concurrent_queries() >= mix.total())
            .min_by_key(|m| m.nodes)
        else {
            eprintln!(
                "table2: no configured machine fits the {}+{} mix; skipping",
                mix.bfs, mix.cc
            );
            continue;
        };
        let machine = Machine::new(mcfg.clone());
        let coord = Coordinator::new(&h.g, machine);

        let queries = planner::mix_queries(&h.g, *mix, h.cfg.workload.source_seed);
        let conc = coord.run(&queries, Policy::Concurrent)?;
        let seq_order = planner::sequential_mix_order(&queries);
        let seq = coord.run(&seq_order, Policy::Sequential)?;

        rows.push(Table2Row {
            machine: mcfg.name.clone(),
            mix: *mix,
            concurrent_s: conc.makespan_s,
            sequential_s: seq.makespan_s,
        });
    }
    Ok(Table2Data { rows })
}

pub fn report(h: &Harness) -> Result<Table2Data> {
    let data = run(h)?;
    println!("== Table II: concurrent mix of BFS and CC ==");
    println!("{}", data.table().render());
    let p = h.save_csv(&data.table(), "table2_mixed")?;
    println!("csv: {p}");
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::ExperimentConfig;
    use crate::config::workload::GraphConfig;

    #[test]
    fn mixes_route_to_fitting_machines_and_improve() {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.graph = GraphConfig::with_scale(11);
        cfg.workload.query_counts = vec![1];
        // A small mix (fits 8 nodes) and one that only fits 32 nodes.
        cfg.workload.mixes = vec![
            MixPoint { bfs: 16, cc: 4 },
            MixPoint { bfs: 300, cc: 20 },
        ];
        let h = Harness::new(cfg).unwrap();
        let d = run(&h).unwrap();
        assert_eq!(d.rows.len(), 2);
        assert_eq!(d.rows[0].machine, "pathfinder-8");
        assert_eq!(d.rows[1].machine, "pathfinder-32");
        for r in &d.rows {
            assert!(
                r.improvement_pct() > 30.0,
                "{}: {:.0}%",
                r.machine,
                r.improvement_pct()
            );
            assert!(r.concurrent_s < r.sequential_s);
        }
    }
}
