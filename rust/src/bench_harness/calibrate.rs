//! Calibration report: the anchors tying the simulator's free parameters
//! to the paper's published numbers (EXPERIMENTS.md §Calibration).
//!
//! Paper anchors (scale-25 graph, 522 M undirected edges):
//!
//! * 8-node solo BFS           = 3.47 s   (Table III column 1)
//! * 8-node 128 concurrent BFS = 226.30 s (Table III) → 1.77 s/query
//! * solo/concurrent-throughput ratio ≈ 2.0 on 8 nodes
//! * 32-node solo BFS          = 1.04 s
//! * improvement bands: >100 % (8 nodes), 81–97 % (32 nodes)
//!
//! This report prints the simulator's equivalents at the configured scale
//! (absolute values scale with the graph; the *ratios* are the contract).

use anyhow::Result;

use crate::coordinator::Policy;
use crate::util::format::{fmt_s, TextTable};

use super::context::Harness;

#[derive(Debug, Clone)]
pub struct CalibrationData {
    pub table: TextTable,
    /// (machine, solo_s, conc_per_query_s, ratio).
    pub ratios: Vec<(String, f64, f64, f64)>,
}

pub fn run(h: &Harness) -> Result<CalibrationData> {
    let mut t = TextTable::new(vec![
        "machine",
        "solo BFS (s)",
        "128-conc/query (s)",
        "solo/conc ratio",
        "channel util (conc)",
    ]);
    let mut ratios = Vec::new();
    for bench in h.benches() {
        let k = 128.min(bench.specs.len());
        let solo = bench
            .coordinator
            .run_specs(&bench.queries[..1], &bench.specs[..1], Policy::Concurrent)?
            .makespan_s;
        let conc = bench.coordinator.run_specs(
            &bench.queries[..k],
            &bench.specs[..k],
            Policy::Concurrent,
        )?;
        let per_query = conc.makespan_s / k as f64;
        let ratio = solo / per_query;
        t.row(vec![
            bench.name().to_string(),
            fmt_s(solo),
            fmt_s(per_query),
            format!("{ratio:.2}"),
            format!("{:.0}%", conc.mean_channel_utilization * 100.0),
        ]);
        ratios.push((bench.name().to_string(), solo, per_query, ratio));
    }
    Ok(CalibrationData { table: t, ratios })
}

pub fn report(h: &Harness) -> Result<CalibrationData> {
    let data = run(h)?;
    println!("== Calibration anchors (paper: 8n ratio ~2.0, 32n ratio ~1.6-1.9) ==");
    println!("{}", data.table.render());
    println!(
        "graph: scale {} ({} vertices, {} directed edges); paper: scale 25",
        h.cfg.workload.graph.scale,
        h.g.n(),
        h.g.m_directed()
    );
    let p = h.save_csv(&data.table, "calibration")?;
    println!("csv: {p}");
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::ExperimentConfig;
    use crate::config::workload::GraphConfig;

    #[test]
    fn solo_concurrent_ratio_near_paper() {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.graph = GraphConfig::with_scale(12);
        cfg.workload.query_counts = vec![64];
        cfg.workload.mixes.clear();
        let h = Harness::new(cfg).unwrap();
        let d = run(&h).unwrap();
        let (_, _, _, ratio8) = d.ratios[0];
        // Paper: 3.47 / 1.77 ~= 1.96 on 8 nodes. At scale 12 the
        // level-sync/latency terms still dominate and inflate the ratio;
        // the tight band is asserted at scale >= 14 in rust/tests/
        // e2e_tests.rs — here we only guard the plumbing and direction.
        assert!(ratio8 > 1.5 && ratio8 < 5.0, "8-node ratio {ratio8:.2}");
    }
}
