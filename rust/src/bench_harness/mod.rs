//! The evaluation harness: regenerates every table and figure of the
//! paper's §IV on the simulated Pathfinder + the PJRT baseline engine.
//!
//! | paper artifact | module | CLI |
//! |---|---|---|
//! | Fig. 3 (conc vs seq BFS times)        | [`fig3`]    | `pathfinder experiment fig3` |
//! | Fig. 4 (improvement %)                | [`fig4`]    | `pathfinder experiment fig4` |
//! | Table I (per-BFS quantiles)           | [`table1`]  | `pathfinder experiment table1` |
//! | Table II (BFS+CC mixes)               | [`table2`]  | `pathfinder experiment table2` |
//! | Table III (+ Fig. 5, RedisGraph)      | [`table3`]  | `pathfinder experiment table3` |
//! | §IV-B scaling & context exhaustion    | [`scaling`] | `pathfinder experiment scaling` |
//! | design-choice ablations (beyond paper)| [`ablation`]| `pathfinder experiment ablation` |
//! | calibration anchors                   | [`calibrate`]| `pathfinder calibrate` |
//!
//! Every experiment prints the paper-shaped table and writes a CSV under
//! the experiment's results dir.

pub mod ablation;
pub mod calibrate;
pub mod context;
pub mod fig3;
pub mod fig4;
pub mod scaling;
pub mod table1;
pub mod table2;
pub mod table3;

pub use context::Harness;
