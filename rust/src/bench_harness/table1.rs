//! Table I: quantiles (0/25/50/75/95/99/100 %) of the *average time per
//! concurrent BFS*, per machine. The paper prints the five-number columns;
//! the p95/p99 tail columns are the serving-side signal the benchmarking
//! guides ask for.
//!
//! Following the paper's construction: each concurrent sample point (one
//! query count from the Fig. 3 sweep) yields one average-time-per-BFS
//! value (total concurrent time / number of queries — the paper's 12
//! samples on 8 nodes, 28 on 32); the table summarizes the distribution of
//! those averages.

use anyhow::Result;

use crate::coordinator::Policy;
use crate::util::format::TextTable;
use crate::util::stats::Quantiles;

use super::context::Harness;

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub machine: String,
    pub samples: usize,
    pub quantiles: Quantiles,
}

#[derive(Debug, Clone)]
pub struct Table1Data {
    pub rows: Vec<Table1Row>,
}

impl Table1Data {
    pub fn table(&self) -> TextTable {
        let mut t = TextTable::new(vec![
            "machine", "samples", "0%", "25%", "50%", "75%", "95%", "99%", "100%",
        ]);
        for r in &self.rows {
            let q = &r.quantiles;
            t.row(vec![
                r.machine.clone(),
                r.samples.to_string(),
                format!("{:.4}", q.q0),
                format!("{:.4}", q.q25),
                format!("{:.4}", q.q50),
                format!("{:.4}", q.q75),
                format!("{:.4}", q.q95),
                format!("{:.4}", q.q99),
                format!("{:.4}", q.q100),
            ]);
        }
        t
    }
}

pub fn run(h: &Harness) -> Result<Table1Data> {
    let mut rows = Vec::new();
    for bench in h.benches() {
        let counts = bench.counts(&h.cfg.workload.query_counts);
        let mut avgs = Vec::new();
        for &k in &counts {
            if k < 2 {
                continue; // a single query is not a concurrency sample
            }
            let conc = bench.coordinator.run_specs(
                &bench.queries[..k],
                &bench.specs[..k],
                Policy::Concurrent,
            )?;
            avgs.push(conc.makespan_s / k as f64);
        }
        if avgs.is_empty() {
            continue;
        }
        rows.push(Table1Row {
            machine: bench.name().to_string(),
            samples: avgs.len(),
            quantiles: Quantiles::from_samples(&avgs),
        });
    }
    Ok(Table1Data { rows })
}

pub fn report(h: &Harness) -> Result<Table1Data> {
    let data = run(h)?;
    println!("== Table I: quantiles of the average time (s) per concurrent BFS ==");
    println!("{}", data.table().render());
    let p = h.save_csv(&data.table(), "table1_quantiles")?;
    println!("csv: {p}");
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::ExperimentConfig;
    use crate::config::workload::GraphConfig;

    #[test]
    fn quantiles_ordered_and_32_faster_than_8() {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.graph = GraphConfig::with_scale(11);
        cfg.workload.query_counts = vec![4, 8, 16, 24];
        cfg.workload.mixes.clear();
        let h = Harness::new(cfg).unwrap();
        let d = run(&h).unwrap();
        assert_eq!(d.rows.len(), 2);
        for r in &d.rows {
            let q = &r.quantiles;
            assert!(q.q0 <= q.q25 && q.q25 <= q.q50 && q.q50 <= q.q75);
            assert!(q.q75 <= q.q95 && q.q95 <= q.q99 && q.q99 <= q.q100);
            assert_eq!(r.samples, 4);
        }
        // Paper: per-BFS averages drop from 1.77–3.97 s (8 nodes) to
        // 0.61–1.22 s (32 nodes) — the 32-node machine is faster per query.
        assert!(d.rows[1].quantiles.q50 < d.rows[0].quantiles.q50);
    }
}
