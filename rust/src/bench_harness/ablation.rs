//! Ablations beyond the paper — the what-ifs its §VI asks for:
//!
//! * **MSP write priority** (§IV-C: "likely is because of the relative
//!   priorities of read and write ... at the memory-side processors"):
//!   sweep `msp_write_priority` and watch the mixed-workload concurrent
//!   time move.
//! * **Healthy 32-node machine** (§IV-B's hardware issues): rerun the
//!   Fig. 4 point on `pathfinder-32-healthy` to quantify what the broken
//!   chassis cost.
//! * **Spawn efficiency**: the calibrated single-query parallelism deficit
//!   is the source of the concurrency win; sweeping it shows how the
//!   improvement would collapse if one query could saturate the machine.

use anyhow::Result;

use crate::config::machine::MachineConfig;
use crate::config::workload::MixPoint;
use crate::coordinator::{planner, Coordinator, Policy};
use crate::sim::machine::Machine;
use crate::util::format::{fmt_pct, fmt_s, TextTable};
use crate::util::stats::improvement_pct;

use super::context::Harness;

#[derive(Debug, Clone)]
pub struct AblationData {
    pub msp_priority: TextTable,
    pub healthy_32: TextTable,
    pub spawn_efficiency: TextTable,
}

/// Sweep MSP write priority on a mixed workload (Table II's stress case).
fn msp_priority_sweep(h: &Harness, mix: MixPoint) -> Result<TextTable> {
    let mut t = TextTable::new(vec!["msp_write_priority", "conc. mixed time (s)"]);
    for prio in [0.5, 0.75, 1.0, 1.5, 2.0] {
        let mut cfg = h.cfg.machines[0].clone();
        cfg.msp_write_priority = prio;
        let coord = Coordinator::new(&h.g, Machine::new(cfg));
        let queries = planner::mix_queries(&h.g, mix, h.cfg.workload.source_seed);
        let rep = coord.run(&queries, Policy::Concurrent)?;
        t.row(vec![format!("{prio:.2}"), fmt_s(rep.makespan_s)]);
    }
    Ok(t)
}

/// Degraded vs hypothetical healthy 32-node machine at one Fig. 4 point.
fn healthy_32(h: &Harness, queries: usize) -> Result<TextTable> {
    let mut t = TextTable::new(vec![
        "machine",
        "concurrent (s)",
        "sequential (s)",
        "improvement",
    ]);
    for cfg in [MachineConfig::pathfinder_32(), MachineConfig::pathfinder_32_healthy()] {
        let coord = Coordinator::new(&h.g, Machine::new(cfg.clone()));
        let qs = planner::bfs_queries(&h.g, queries, h.cfg.workload.source_seed);
        let conc = coord.run(&qs, Policy::Concurrent)?;
        let seq = coord.run(&qs, Policy::Sequential)?;
        t.row(vec![
            cfg.name.clone(),
            fmt_s(conc.makespan_s),
            fmt_s(seq.makespan_s),
            fmt_pct(improvement_pct(seq.makespan_s, conc.makespan_s)),
        ]);
    }
    Ok(t)
}

/// Sweep the single-query spawn efficiency on the 8-node machine.
fn spawn_sweep(h: &Harness, queries: usize) -> Result<TextTable> {
    let mut t = TextTable::new(vec!["spawn_efficiency", "improvement (conc vs seq)"]);
    for eta in [0.2, 0.41, 0.6, 0.8, 1.0] {
        let mut cfg = h.cfg.machines[0].clone();
        cfg.spawn_efficiency = eta;
        let coord = Coordinator::new(&h.g, Machine::new(cfg));
        let qs = planner::bfs_queries(&h.g, queries, h.cfg.workload.source_seed);
        let conc = coord.run(&qs, Policy::Concurrent)?;
        let seq = coord.run(&qs, Policy::Sequential)?;
        t.row(vec![
            format!("{eta:.2}"),
            fmt_pct(improvement_pct(seq.makespan_s, conc.makespan_s)),
        ]);
    }
    Ok(t)
}

pub fn run(h: &Harness) -> Result<AblationData> {
    let mix = h
        .cfg
        .workload
        .mixes
        .first()
        .copied()
        .unwrap_or(MixPoint { bfs: 16, cc: 4 });
    // Keep the ablation workload modest: it is a sensitivity study.
    let mix = MixPoint { bfs: mix.bfs.min(32), cc: mix.cc.min(8) };
    let queries = 32.min(h.cfg.machines[0].max_concurrent_queries());
    Ok(AblationData {
        msp_priority: msp_priority_sweep(h, mix)?,
        healthy_32: healthy_32(h, queries)?,
        spawn_efficiency: spawn_sweep(h, queries)?,
    })
}

pub fn report(h: &Harness) -> Result<AblationData> {
    let data = run(h)?;
    println!("== Ablation: MSP read/write priority (mixed workload, §IV-C) ==");
    println!("{}", data.msp_priority.render());
    println!("== Ablation: degraded vs healthy 32-node machine (§IV-B) ==");
    println!("{}", data.healthy_32.render());
    println!("== Ablation: single-query spawn efficiency (the headroom source) ==");
    println!("{}", data.spawn_efficiency.render());
    h.save_csv(&data.msp_priority, "ablation_msp_priority")?;
    h.save_csv(&data.healthy_32, "ablation_healthy32")?;
    let p = h.save_csv(&data.spawn_efficiency, "ablation_spawn_efficiency")?;
    println!("csv: {p} (+ ablation_msp_priority, ablation_healthy32)");
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::ExperimentConfig;
    use crate::config::workload::GraphConfig;

    #[test]
    fn ablations_produce_tables() {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.graph = GraphConfig::with_scale(10);
        cfg.workload.query_counts = vec![8];
        cfg.workload.mixes = vec![MixPoint { bfs: 8, cc: 2 }];
        let h = Harness::new(cfg).unwrap();
        let d = run(&h).unwrap();
        assert!(!d.msp_priority.is_empty());
        assert!(!d.healthy_32.is_empty());
        assert!(!d.spawn_efficiency.is_empty());
    }
}
