//! Shared experiment context: graph, machines, prepared query specs.

use anyhow::Result;

use crate::config::experiment::ExperimentConfig;
use crate::config::machine::MachineConfig;
use crate::coordinator::planner;
use crate::coordinator::{Coordinator, QueryRequest};
use crate::graph::builder::build_undirected_csr;
use crate::graph::csr::Csr;
use crate::graph::rmat::Rmat;
use crate::sim::flow::QuerySpec;
use crate::sim::machine::Machine;
use crate::util::format::TextTable;

/// Everything an experiment needs, built once: the graph and per-machine
/// coordinators with prepared BFS specs (preparation is the expensive part
/// — each query is functionally executed to emit demand — so sample points
/// share one preparation at the maximum query count and slice it).
pub struct Harness {
    pub cfg: ExperimentConfig,
    pub g: Csr,
}

/// A machine bound to the harness graph with its BFS queries pre-prepared.
pub struct MachineBench<'g> {
    pub coordinator: Coordinator<'g>,
    /// The prepared BFS requests (max_queries of them).
    pub queries: Vec<QueryRequest>,
    pub specs: Vec<QuerySpec>,
}

impl MachineBench<'_> {
    /// Machine preset name.
    pub fn name(&self) -> &str {
        &self.coordinator.machine().cfg.name
    }

    /// Query counts applicable to this machine: the workload counts
    /// filtered to the machine's context capacity and the prepared size.
    pub fn counts(&self, all: &[usize]) -> Vec<usize> {
        all.iter()
            .copied()
            .filter(|&k| k <= self.specs.len() && k <= self.coordinator.capacity())
            .collect()
    }
}

impl Harness {
    /// Build the graph described by the experiment config.
    pub fn new(cfg: ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let gcfg = &cfg.workload.graph;
        let rmat = Rmat::new(gcfg.clone());
        let g = build_undirected_csr(gcfg.n_vertices() as usize, &rmat.edges());
        Ok(Harness { cfg, g })
    }

    /// The largest query count any experiment will use on `m`.
    fn max_queries(&self, m: &MachineConfig) -> usize {
        let wl = &self.cfg.workload;
        let from_counts = wl.query_counts.iter().copied().max().unwrap_or(1);
        let from_mixes = wl.mixes.iter().map(|x| x.bfs).max().unwrap_or(0);
        from_counts.max(from_mixes).min(m.max_concurrent_queries())
    }

    /// Bind a machine: build its coordinator and prepare its BFS specs.
    pub fn bench(&self, m: &MachineConfig) -> MachineBench<'_> {
        let machine = Machine::new(m.clone());
        let coordinator = Coordinator::new(&self.g, machine);
        let k = self.max_queries(m);
        let queries = planner::bfs_queries(&self.g, k, self.cfg.workload.source_seed);
        let specs = coordinator.prepare(&queries);
        MachineBench { coordinator, queries, specs }
    }

    /// All configured machines, bound.
    pub fn benches(&self) -> Vec<MachineBench<'_>> {
        self.cfg.machines.iter().map(|m| self.bench(m)).collect()
    }

    /// Write a table's CSV into the results dir (creating it) and return
    /// the path as a display string.
    pub fn save_csv(&self, table: &TextTable, name: &str) -> Result<String> {
        std::fs::create_dir_all(&self.cfg.results_dir)?;
        let p = table.write_csv(&self.cfg.results_dir, name)?;
        Ok(p.display().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::GraphConfig;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.graph = GraphConfig::with_scale(10);
        cfg.workload.query_counts = vec![1, 4, 8];
        cfg.workload.mixes = vec![crate::config::workload::MixPoint { bfs: 6, cc: 2 }];
        cfg.results_dir = std::env::temp_dir().join("pfq-harness-test");
        cfg
    }

    #[test]
    fn harness_builds_and_prepares() {
        let h = Harness::new(tiny_cfg()).unwrap();
        assert_eq!(h.g.n(), 1 << 10);
        let benches = h.benches();
        assert_eq!(benches.len(), 2);
        let b8 = &benches[0];
        assert_eq!(b8.name(), "pathfinder-8");
        assert_eq!(b8.specs.len(), 8);
        assert_eq!(b8.counts(&[1, 4, 8, 999]), vec![1, 4, 8]);
    }

    #[test]
    fn counts_respect_capacity() {
        let mut cfg = tiny_cfg();
        cfg.workload.query_counts = vec![1, 4];
        cfg.machines[0].ctx_mem_per_node_bytes = 16 << 20; // capacity 8
        let h = Harness::new(cfg).unwrap();
        let b = h.bench(&h.cfg.machines[0].clone());
        assert!(b.specs.len() <= 8);
    }
}
