//! Table III: concurrent-BFS times for RedisGraph Enterprise (modeled,
//! optionally anchored to a real PJRT GraphBLAS measurement) vs the
//! Pathfinder (simulated), with the paper's client-overhead-adjusted
//! speed-ups. Prints Figure 5's query template alongside.

use anyhow::Result;

use crate::baseline::redisgraph::{adjusted_speedup, query_template, ClientOverhead};
use crate::baseline::xeon::XeonModel;
use crate::coordinator::Policy;
use crate::util::format::{fmt_s, TextTable};

use super::context::Harness;

/// Query counts of the paper's Table III columns.
pub const COLUMNS: [usize; 6] = [1, 8, 16, 32, 64, 128];

#[derive(Debug, Clone)]
pub struct Table3Data {
    pub counts: Vec<usize>,
    /// Modeled RedisGraph totals (s).
    pub redisgraph_s: Vec<f64>,
    /// Simulated Pathfinder totals (s), one row per machine.
    pub pathfinder: Vec<(String, Vec<f64>)>,
    /// The client/server overhead applied to the adjusted speed-ups.
    pub overhead: ClientOverhead,
    /// If the PJRT engine was run to anchor the model: (measured single
    /// query s at artifact scale, artifact-graph directed edges).
    pub anchor: Option<(f64, usize)>,
}

impl Table3Data {
    pub fn table(&self) -> TextTable {
        let mut header = vec!["".to_string()];
        header.extend(self.counts.iter().map(|q| q.to_string()));
        let mut t = TextTable::new(header);
        let mut rg_row = vec!["RedisGraph (modeled)".to_string()];
        rg_row.extend(self.redisgraph_s.iter().map(|&s| fmt_s(s)));
        t.row(rg_row);
        for (name, times) in &self.pathfinder {
            let mut row = vec![format!("{name} (simulated)")];
            row.extend(times.iter().map(|&s| fmt_s(s)));
            t.row(row);
        }
        for (name, times) in &self.pathfinder {
            let mut row = vec![format!("{name} adj. speed-up")];
            row.extend(
                times
                    .iter()
                    .zip(&self.redisgraph_s)
                    .map(|(&pf, &rg)| {
                        format!("{:.2}", adjusted_speedup(rg, pf, self.overhead))
                    }),
            );
            t.row(row);
        }
        t
    }

    /// Adjusted speed-up of one machine at one column.
    pub fn speedup(&self, machine: &str, q: usize) -> Option<f64> {
        let col = self.counts.iter().position(|&c| c == q)?;
        let (_, times) = self.pathfinder.iter().find(|(n, _)| n == machine)?;
        Some(adjusted_speedup(self.redisgraph_s[col], times[col], self.overhead))
    }
}

/// Run Table III. If `engine` is supplied, the Xeon model's absolute scale
/// is anchored to a real single-query measurement of the PJRT GraphBLAS
/// engine on an artifact-sized slice of the workload graph.
pub fn run(h: &Harness, engine: Option<&crate::runtime::Engine>) -> Result<Table3Data> {
    // --- RedisGraph column. ---
    let (xeon, anchor) = match engine {
        Some(eng) => {
            let n_art = eng.manifest().n;
            // Generate a small R-MAT matching the artifact dimension.
            let scale = (n_art as f64).log2() as u32;
            let gcfg = crate::config::workload::GraphConfig {
                scale: scale.min(h.cfg.workload.graph.scale),
                ..h.cfg.workload.graph.clone()
            };
            let rmat = crate::graph::rmat::Rmat::new(gcfg.clone());
            let small = crate::graph::builder::build_undirected_csr(
                gcfg.n_vertices() as usize,
                &rmat.edges(),
            );
            let gb = crate::baseline::GraphBlasEngine::new(eng, &small)?;
            let src = crate::graph::sample::bfs_sources(&small, 1, 7)[0];
            let res = gb.bfs(&[src])?;
            let anchor = (res.exec_s, small.m_directed());
            (
                XeonModel::anchor_measured(res.exec_s, small.m_directed(), h.g.m_directed()),
                Some(anchor),
            )
        }
        None => (
            // Unanchored: the paper's own absolute scale, rescaled from
            // the paper's graph to ours by directed edge count.
            XeonModel {
                base_query_s: 5.0 * h.g.m_directed() as f64 / 1_044_951_226.0,
                hw_threads: 128,
            },
            None,
        ),
    };

    let counts: Vec<usize> = COLUMNS.to_vec();
    let redisgraph_s: Vec<f64> = counts.iter().map(|&q| xeon.total_s(q)).collect();
    let overhead = ClientOverhead::from_single_query(xeon.total_s(1));

    // --- Pathfinder rows (simulated). ---
    let mut pathfinder = Vec::new();
    for bench in h.benches() {
        let mut times = Vec::with_capacity(counts.len());
        for &q in &counts {
            anyhow::ensure!(
                q <= bench.specs.len(),
                "table3 needs {q} prepared queries on {}; increase query_counts",
                bench.name()
            );
            let rep = bench.coordinator.run_specs(
                &bench.queries[..q],
                &bench.specs[..q],
                Policy::Concurrent,
            )?;
            times.push(rep.makespan_s);
        }
        pathfinder.push((bench.name().to_string(), times));
    }

    Ok(Table3Data { counts, redisgraph_s, pathfinder, overhead, anchor })
}

pub fn report(h: &Harness, engine: Option<&crate::runtime::Engine>) -> Result<Table3Data> {
    let data = run(h, engine)?;
    println!("== Table III: RedisGraph vs Pathfinder, concurrent BFS (s) ==");
    println!("(Fig. 5 query: {})", query_template(42));
    if let Some((s, m)) = data.anchor {
        println!(
            "Xeon model anchored to PJRT GraphBLAS engine: {:.4}s / query at {m} directed edges",
            s
        );
    }
    println!("{}", data.table().render());
    let p = h.save_csv(&data.table(), "table3_redisgraph")?;
    println!("csv: {p}");
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::ExperimentConfig;
    use crate::config::workload::GraphConfig;

    #[test]
    fn speedups_grow_with_concurrency_like_the_paper() {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.graph = GraphConfig::with_scale(11);
        cfg.workload.query_counts = vec![128];
        cfg.workload.mixes.clear();
        let h = Harness::new(cfg).unwrap();
        let d = run(&h, None).unwrap();

        // Shape checks against the paper's Table III:
        // 32 nodes beats 8 nodes at every column.
        for (i, _) in d.counts.iter().enumerate() {
            assert!(d.pathfinder[1].1[i] < d.pathfinder[0].1[i]);
        }
        // The adjusted speed-up grows with concurrency and the 128-query
        // column is the largest (RedisGraph oversubscribes).
        let s32: Vec<f64> =
            d.counts.iter().map(|&q| d.speedup("pathfinder-32", q).unwrap()).collect();
        assert!(s32.windows(2).all(|w| w[0] <= w[1] + 1e-9), "{s32:?}");
        assert!(s32.last().unwrap() > &s32[1]);
        // At a single query the adjusted ratio is near or below 1
        // (the paper reports 0.59 / 0.83 — RedisGraph competitive).
        assert!(d.speedup("pathfinder-8", 1).unwrap() < 1.2);
    }
}
