//! Plain-text table rendering for the experiment harness — every figure and
//! table in the paper is regenerated as an aligned text table plus a CSV.

/// A simple column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        TextTable {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with right-aligned numeric-ish columns.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (no quoting needed for our numeric content).
    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV beside the printed output (results/<name>.csv).
    pub fn write_csv(&self, dir: &std::path::Path, name: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format seconds with 2–3 significant digits like the paper's tables.
pub fn fmt_s(seconds: f64) -> String {
    if seconds >= 100.0 {
        format!("{seconds:.1}")
    } else if seconds >= 1.0 {
        format!("{seconds:.2}")
    } else {
        format!("{seconds:.3}")
    }
}

/// Format a ratio/speed-up like the paper (e.g. "19.2x").
pub fn fmt_x(ratio: f64) -> String {
    if ratio >= 10.0 {
        format!("{ratio:.1}x")
    } else {
        format!("{ratio:.2}x")
    }
}

/// Format a percentage improvement.
pub fn fmt_pct(p: f64) -> String {
    format!("{p:.1}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new(vec!["a", "value"]);
        t.row(vec!["1", "5"]).row(vec!["22", "1707"]);
        let s = t.render();
        assert!(s.contains(" a  value"));
        assert!(s.contains("22   1707"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = TextTable::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_round_trip_shape() {
        let mut t = TextTable::new(vec!["x", "y"]);
        t.row(vec!["1", "2"]);
        assert_eq!(t.to_csv(), "x,y\n1,2\n");
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_s(226.30442), "226.3");
        assert_eq!(fmt_s(3.4712), "3.47");
        assert_eq!(fmt_s(0.59), "0.590");
        assert_eq!(fmt_x(19.17), "19.2x");
        assert_eq!(fmt_x(5.07), "5.07x");
        assert_eq!(fmt_pct(70.07), "70.1%");
    }
}
