//! Scoped data-parallel helpers over std threads (no rayon offline).
//!
//! The heavy host-side work — generating R-MAT edges, tracing hundreds of
//! BFS queries to build demand profiles — is embarrassingly parallel over
//! chunks, so a static chunk split over `available_parallelism` threads is
//! all we need.

/// Number of worker threads to use.
pub fn workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parallel map over items: applies `f` to every element, preserving order.
/// `f` must be `Sync` (called from many threads).
pub fn par_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let nw = workers().min(n);
    if nw <= 1 {
        return items.iter().map(&f).collect();
    }
    let chunk = n.div_ceil(nw);
    let mut out: Vec<Option<U>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let out_chunks: Vec<&mut [Option<U>]> = out.chunks_mut(chunk).collect();
    std::thread::scope(|scope| {
        for (ci, out_chunk) in out_chunks.into_iter().enumerate() {
            let f = &f;
            let in_chunk = &items[ci * chunk..(ci * chunk + out_chunk.len())];
            scope.spawn(move || {
                for (slot, item) in out_chunk.iter_mut().zip(in_chunk) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    out.into_iter().map(|x| x.expect("worker panicked")).collect()
}

/// Parallel map over an index range [0, n).
pub fn par_map_range<U, F>(n: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    par_map(&idx, |&i| f(i))
}

/// Parallel unstable sort: split into per-thread sorted runs, then k-way
/// merge. Falls back to std sort for small inputs.
pub fn par_sort_unstable<T: Ord + Send + Copy>(xs: &mut Vec<T>) {
    const SERIAL_CUTOFF: usize = 1 << 16;
    if xs.len() < SERIAL_CUTOFF || workers() <= 1 {
        xs.sort_unstable();
        return;
    }
    let nw = workers().min(8);
    let chunk = xs.len().div_ceil(nw);
    std::thread::scope(|scope| {
        for part in xs.chunks_mut(chunk) {
            scope.spawn(|| part.sort_unstable());
        }
    });
    // K-way merge of the sorted runs.
    let runs: Vec<&[T]> = xs.chunks(chunk).collect();
    let mut cursors = vec![0usize; runs.len()];
    let mut merged = Vec::with_capacity(xs.len());
    loop {
        let mut best: Option<(usize, T)> = None;
        for (ri, run) in runs.iter().enumerate() {
            if cursors[ri] < run.len() {
                let v = run[cursors[ri]];
                if best.map_or(true, |(_, bv)| v < bv) {
                    best = Some((ri, v));
                }
            }
        }
        match best {
            Some((ri, v)) => {
                merged.push(v);
                cursors[ri] += 1;
            }
            None => break,
        }
    }
    *xs = merged;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<u64> = (0..10_000).collect();
        let ys = par_map(&xs, |&x| x * 2);
        assert!(ys.iter().enumerate().all(|(i, &y)| y == 2 * i as u64));
    }

    #[test]
    fn par_map_empty() {
        let xs: Vec<u32> = vec![];
        assert!(par_map(&xs, |&x| x).is_empty());
    }

    #[test]
    fn par_map_range_matches_serial() {
        let ys = par_map_range(1000, |i| i * i);
        assert!(ys.iter().enumerate().all(|(i, &y)| y == i * i));
    }

    #[test]
    fn par_sort_matches_std() {
        let mut rng = crate::util::rng::SplitMix64::new(5);
        let mut xs: Vec<u64> = (0..200_000).map(|_| rng.next_u64() % 1000).collect();
        let mut want = xs.clone();
        want.sort_unstable();
        par_sort_unstable(&mut xs);
        assert_eq!(xs, want);
    }

    #[test]
    fn par_sort_small_input() {
        let mut xs = vec![3u32, 1, 2];
        par_sort_unstable(&mut xs);
        assert_eq!(xs, vec![1, 2, 3]);
    }
}
