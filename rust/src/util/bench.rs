//! Micro-benchmark harness (offline environment: no criterion).
//!
//! `cargo bench` targets in rust/benches use this: warmup, repeated timed
//! runs, and a median/mean/stddev report. Deliberately minimal — the
//! statistics are what the perf pass in EXPERIMENTS.md §Perf records.

use std::time::{Duration, Instant};

use crate::util::stats;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
}

impl BenchResult {
    pub fn median_s(&self) -> f64 {
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        stats::quantile_sorted(&xs, 0.5)
    }

    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn stddev_s(&self) -> f64 {
        stats::stddev(&self.samples)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} median {:>12}  mean {:>12}  stddev {:>10}  (n={})",
            self.name,
            fmt_duration(self.median_s()),
            fmt_duration(self.mean_s()),
            fmt_duration(self.stddev_s()),
            self.samples.len()
        )
    }
}

/// Format seconds adaptively (ns/us/ms/s).
pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Benchmark runner with warmup + fixed sample count (adaptive iteration
/// batching so fast functions still get meaningful timings).
pub struct Bench {
    warmup: Duration,
    samples: usize,
    min_sample_time: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            samples: 12,
            min_sample_time: Duration::from_millis(20),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick mode for CI: fewer samples, shorter warmup. Activated by the
    /// PFQ_BENCH_QUICK env var in the bench binaries.
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(20),
            samples: 4,
            min_sample_time: Duration::from_millis(2),
            results: Vec::new(),
        }
    }

    pub fn from_env() -> Self {
        if std::env::var("PFQ_BENCH_QUICK").is_ok() {
            Self::quick()
        } else {
            Self::new()
        }
    }

    /// Time `f`, which should return something observable to keep the
    /// optimizer honest (the return value is black-boxed).
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibrate the per-sample iteration count.
        let warm_start = Instant::now();
        let mut iters_per_sample = 1usize;
        let mut one = Duration::ZERO;
        while warm_start.elapsed() < self.warmup {
            let t = Instant::now();
            black_box(f());
            one = t.elapsed();
            if one > self.warmup {
                break;
            }
        }
        if one < self.min_sample_time && one > Duration::ZERO {
            iters_per_sample = (self.min_sample_time.as_secs_f64() / one.as_secs_f64()).ceil() as usize;
            iters_per_sample = iters_per_sample.clamp(1, 1_000_000);
        }

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            samples.push(t.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        let result = BenchResult { name: name.to_string(), samples };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Optimizer barrier (std::hint::black_box wrapper, kept behind one name so
/// bench code reads uniformly).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench::quick();
        let r = b.run("noop-ish", || 1 + 1).clone();
        assert_eq!(r.name, "noop-ish");
        assert_eq!(r.samples.len(), 4);
        assert!(r.median_s() >= 0.0);
    }

    #[test]
    fn fmt_duration_scales() {
        assert!(fmt_duration(3.2e-9).ends_with("ns"));
        assert!(fmt_duration(3.2e-6).ends_with("us"));
        assert!(fmt_duration(3.2e-3).ends_with("ms"));
        assert!(fmt_duration(3.2).ends_with("s"));
    }
}
