//! Summary statistics: quantiles, means, and the Table-I style summaries
//! used throughout the evaluation harness.

/// Quantile summary of a sample set: the paper's Table-I five-number
/// columns (min / 25% / median / 75% / max) plus the p95/p99 tail
/// quantiles a serving deployment actually alerts on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quantiles {
    pub q0: f64,
    pub q25: f64,
    pub q50: f64,
    pub q75: f64,
    pub q95: f64,
    pub q99: f64,
    pub q100: f64,
}

impl Quantiles {
    /// Compute from unsorted samples. Panics on empty input; reporting
    /// paths that may legitimately see an empty class (e.g. fully shed)
    /// should use [`Quantiles::try_from_samples`].
    pub fn from_samples(samples: &[f64]) -> Quantiles {
        Quantiles::try_from_samples(samples).expect("quantiles of empty sample set")
    }

    /// Non-panicking [`Quantiles::from_samples`]: `None` on empty input.
    pub fn try_from_samples(samples: &[f64]) -> Option<Quantiles> {
        if samples.is_empty() {
            return None;
        }
        let mut xs = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        Some(Quantiles {
            q0: quantile_sorted(&xs, 0.0),
            q25: quantile_sorted(&xs, 0.25),
            q50: quantile_sorted(&xs, 0.50),
            q75: quantile_sorted(&xs, 0.75),
            q95: quantile_sorted(&xs, 0.95),
            q99: quantile_sorted(&xs, 0.99),
            q100: quantile_sorted(&xs, 1.0),
        })
    }

    /// Max-min spread, as discussed for Table I ("the min-max spread is
    /// 2.2 s / 0.61 s").
    pub fn spread(&self) -> f64 {
        self.q100 - self.q0
    }

    /// Compact operator rendering in seconds:
    /// `p0=… p50=… p95=… p99=… p100=…`.
    pub fn latency_line(&self) -> String {
        format!(
            "p0={:.3}s p50={:.3}s p95={:.3}s p99={:.3}s p100={:.3}s",
            self.q0, self.q50, self.q95, self.q99, self.q100
        )
    }
}

/// Linear-interpolated quantile of an ascending-sorted slice (type-7, the
/// R/NumPy default).
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Arithmetic mean. Panics on empty input; use [`try_mean`] on paths
/// where an empty sample set is a legitimate outcome.
pub fn mean(xs: &[f64]) -> f64 {
    try_mean(xs).expect("mean of empty sample set")
}

/// Non-panicking [`mean`]: `None` on empty input (a fully-shed class
/// must not crash report rendering).
pub fn try_mean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    Some(xs.iter().sum::<f64>() / xs.len() as f64)
}

/// Sample standard deviation (n-1 denominator); 0 for a single sample.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Relative improvement of `new` over `old` in percent, in the paper's
/// convention: how much *faster* the new (concurrent) time is relative to
/// itself — e.g. seq 884 s vs conc 467 s => 89 %.
pub fn improvement_pct(sequential: f64, concurrent: f64) -> f64 {
    assert!(concurrent > 0.0);
    (sequential - concurrent) / concurrent * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_of_known_set() {
        let q = Quantiles::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(q.q0, 1.0);
        assert_eq!(q.q25, 2.0);
        assert_eq!(q.q50, 3.0);
        assert_eq!(q.q75, 4.0);
        // Type-7 interpolation on 5 samples: pos = p * 4.
        assert!((q.q95 - 4.8).abs() < 1e-12);
        assert!((q.q99 - 4.96).abs() < 1e-12);
        assert_eq!(q.q100, 5.0);
    }

    #[test]
    fn latency_line_surfaces_tail_quantiles() {
        let q = Quantiles::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        let line = q.latency_line();
        assert!(line.contains("p95=4.800s"), "{line}");
        assert!(line.contains("p99=4.960s"), "{line}");
        assert!(line.starts_with("p0=1.000s"), "{line}");
    }

    #[test]
    fn quantiles_interpolate() {
        let q = Quantiles::from_samples(&[0.0, 1.0]);
        assert!((q.q50 - 0.5).abs() < 1e-12);
        assert!((q.q25 - 0.25).abs() < 1e-12);
    }

    #[test]
    fn quantiles_single_sample() {
        let q = Quantiles::from_samples(&[7.5]);
        assert_eq!(q.q0, 7.5);
        assert_eq!(q.q100, 7.5);
        assert_eq!(q.spread(), 0.0);
    }

    #[test]
    fn quantiles_unsorted_input() {
        let q = Quantiles::from_samples(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(q.q0, 1.0);
        assert_eq!(q.q100, 5.0);
    }

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(stddev(&[3.0]), 0.0);
    }

    #[test]
    fn try_variants_are_none_on_empty_and_agree_otherwise() {
        assert_eq!(try_mean(&[]), None);
        assert_eq!(Quantiles::try_from_samples(&[]), None);
        assert_eq!(try_mean(&[2.0, 4.0]), Some(3.0));
        assert_eq!(
            Quantiles::try_from_samples(&[1.0, 2.0, 3.0]),
            Some(Quantiles::from_samples(&[1.0, 2.0, 3.0]))
        );
    }

    #[test]
    fn improvement_matches_paper_fig3_numbers() {
        // 32-node, 750 queries: 884 s sequential vs 467 s concurrent => ~89 %.
        let imp = improvement_pct(884.0, 467.0);
        assert!((imp - 89.29).abs() < 0.1, "{imp}");
        // 8-node, 128 queries: 493 s vs 226 s => ~118 % (the ">2x" claim).
        let imp8 = improvement_pct(493.0, 226.0);
        assert!(imp8 > 100.0);
    }
}
