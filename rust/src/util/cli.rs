//! Tiny CLI argument parser (offline environment: no clap).
//!
//! Supports the subcommand + `--flag value` / `--flag` style the
//! `pathfinder` binary and the examples use.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: a positional subcommand list plus --key options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// First positional argument (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// String option.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    /// Typed numeric option.
    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => match s.parse() {
                Ok(v) => Ok(Some(v)),
                Err(e) => bail!("bad value for --{name}: {e}"),
            },
        }
    }

    /// Typed numeric option with default.
    pub fn opt_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }

    /// Comma-separated list option, e.g. `--counts 1,8,64`.
    pub fn opt_list<T: std::str::FromStr>(&self, name: &str) -> Result<Option<Vec<T>>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => {
                let mut out = Vec::new();
                for piece in s.split(',') {
                    match piece.trim().parse() {
                        Ok(v) => out.push(v),
                        Err(e) => bail!("bad element '{piece}' in --{name}: {e}"),
                    }
                }
                Ok(Some(out))
            }
        }
    }

    /// Boolean flag presence (`--verbose`).
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Parse a `key=value,key=value,...` list with f64 values — the shape
/// shared by `--mix bfs=0.8,cc=0.2`, `--priority-mix interactive=0.3,...`
/// and `--slo khop=0.05`. Keys are trimmed; empty pieces are skipped;
/// `what` names the list in error messages.
pub fn parse_kv_f64_list<'a>(spec: &'a str, what: &str) -> Result<Vec<(&'a str, f64)>> {
    let mut out = Vec::new();
    for piece in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let Some((key, value)) = piece.split_once('=') else {
            bail!("bad {what} entry {piece:?}: want key=value");
        };
        let value: f64 = value
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("bad {what} value in {piece:?}: {e}"))?;
        out.push((key.trim(), value));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("experiment fig3 --scale 16 --machine pathfinder-8 --verbose");
        assert_eq!(a.subcommand(), Some("experiment"));
        assert_eq!(a.positional[1], "fig3");
        assert_eq!(a.opt("scale"), Some("16"));
        assert_eq!(a.opt("machine"), Some("pathfinder-8"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("run --scale=14 --counts=1,2,3");
        assert_eq!(a.opt_parse_or::<u32>("scale", 0).unwrap(), 14);
        assert_eq!(a.opt_list::<usize>("counts").unwrap().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --scale banana");
        assert!(a.opt_parse::<u32>("scale").is_err());
    }

    #[test]
    fn missing_option_defaults() {
        let a = parse("x");
        assert_eq!(a.opt_or("mode", "both"), "both");
        assert_eq!(a.opt_parse_or("n", 7u32).unwrap(), 7);
        assert!(a.opt_list::<u32>("counts").unwrap().is_none());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --dry-run --scale 10");
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.opt("scale"), Some("10"));
    }

    #[test]
    fn kv_f64_lists() {
        let kv = parse_kv_f64_list("bfs=0.6, cc = 0.4", "mix").unwrap();
        assert_eq!(kv, vec![("bfs", 0.6), ("cc", 0.4)]);
        assert!(parse_kv_f64_list("", "mix").unwrap().is_empty());
        assert!(parse_kv_f64_list("bfs", "mix").is_err());
        let err = parse_kv_f64_list("bfs=x", "mix").unwrap_err().to_string();
        assert!(err.contains("mix"), "{err}");
    }
}
