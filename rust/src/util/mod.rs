//! Small shared utilities: deterministic RNG, summary statistics, formatting.

pub mod bench;
pub mod cli;
pub mod format;
pub mod json;
pub mod parallel;
pub mod rng;
pub mod stats;

/// Simulated time in integer nanoseconds. All simulator clocks use this; it
/// is never derived from the wall clock, keeping every experiment
/// reproducible bit-for-bit.
pub type SimNs = u64;

/// Convert simulated nanoseconds to seconds for reporting.
pub fn ns_to_s(ns: SimNs) -> f64 {
    ns as f64 * 1e-9
}

/// Convert seconds to simulated nanoseconds (saturating at u64::MAX).
pub fn s_to_ns(s: f64) -> SimNs {
    if s <= 0.0 {
        0
    } else {
        (s * 1e9).min(u64::MAX as f64) as SimNs
    }
}
