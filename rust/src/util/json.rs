//! Minimal JSON parser/serializer.
//!
//! The build environment is offline (no serde), so this repo carries its own
//! small JSON implementation. It covers everything we exchange: the AOT
//! `artifacts/manifest.json` written by `python/compile/aot.py`, machine and
//! experiment configs under `configs/`, and experiment result dumps.
//!
//! Numbers are kept as f64 (ints in our data are well below 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, ensure, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    // ---- typed accessors ----------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking for '{key}')"),
        }
    }

    /// Optional field: Ok(None) when absent.
    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let x = self.as_f64()?;
        ensure!(x >= 0.0 && x.fract() == 0.0 && x <= 2f64.powi(53), "not a u64: {x}");
        Ok(x as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// Convenience: numeric field.
    pub fn f64_of(&self, key: &str) -> Result<f64> {
        self.get(key)?.as_f64().with_context(|| format!("field '{key}'"))
    }

    pub fn u64_of(&self, key: &str) -> Result<u64> {
        self.get(key)?.as_u64().with_context(|| format!("field '{key}'"))
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        self.get(key)?.as_usize().with_context(|| format!("field '{key}'"))
    }

    pub fn str_of(&self, key: &str) -> Result<String> {
        Ok(self.get(key)?.as_str()?.to_string())
    }

    // ---- serialization -------------------------------------------------

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn render_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    it.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing --------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.render_pretty())?;
        Ok(())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        ensure!(self.peek()? == b, "expected '{}' at byte {}", b as char, self.pos);
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        ensure!(
            self.bytes[self.pos..].starts_with(lit.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += lit.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            ensure!(self.pos + 4 <= self.bytes.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // Surrogate pairs are not needed for our data;
                            // map lone surrogates to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                b if b < 0x80 => out.push(b as char),
                _ => {
                    // Re-decode the multibyte char from the source.
                    let start = self.pos - 1;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| anyhow!("invalid utf8 at byte {start}"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos = start + c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let x: f64 = text.parse().with_context(|| format!("bad number '{text}'"))?;
        Ok(Json::Num(x))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' got '{}' at byte {}", c as char, self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' got '{}' at byte {}", c as char, self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::str("hi\nthere"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].str_of("b").unwrap(),
            "c"
        );
    }

    #[test]
    fn round_trip() {
        let v = Json::obj(vec![
            ("name", Json::str("pathfinder-8")),
            ("nodes", Json::num(8.0)),
            ("degraded", Json::arr([Json::num(2.0), Json::num(3.0)])),
            ("ok", Json::Bool(true)),
        ]);
        for render in [v.render(), v.render_pretty()] {
            assert_eq!(Json::parse(&render).unwrap(), v);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn real_manifest_shape() {
        // Shape equivalent to python/compile/aot.py output.
        let text = r#"{
          "version": 1, "n": 1024,
          "entries": [
            {"name": "bfs_step_b8_n1024", "kind": "bfs_step", "batch": 8,
             "n": 1024, "path": "bfs_step_b8_n1024.hlo.txt",
             "outputs": ["next_frontier","visited","levels","active"],
             "sha256": "ab"}
          ]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.usize_of("n").unwrap(), 1024);
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.str_of("kind").unwrap(), "bfs_step");
        assert_eq!(e.usize_of("batch").unwrap(), 8);
    }

    #[test]
    fn unicode_and_escapes_round_trip() {
        let v = Json::str("héllo\t\"wörld\" \\ ∞");
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn u64_guard() {
        assert!(Json::Num(1.5).as_u64().is_err());
        assert!(Json::Num(-2.0).as_u64().is_err());
        assert_eq!(Json::Num(42.0).as_u64().unwrap(), 42);
    }
}
