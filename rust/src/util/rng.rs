//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 is the Graph500 reference generator's seeding primitive and is
//! plenty for workload generation; determinism across platforms matters more
//! here than statistical sophistication. All experiment randomness flows
//! from explicit seeds in configs.

/// SplitMix64 PRNG (Steele, Lea & Flood 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Equal seeds give equal streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) (bound > 0). Uses Lemire's method.
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Fork an independent stream (for per-query / per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct values from [0, bound) (k <= bound).
    /// Reproduces the paper's "reproducibly pseudorandomly generated"
    /// unique BFS source vertices.
    pub fn sample_distinct(&mut self, bound: u64, k: usize) -> Vec<u64> {
        assert!(k as u64 <= bound, "cannot sample {k} distinct from {bound}");
        // Floyd's algorithm: O(k) expected, no O(bound) allocation.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (bound - k as u64)..bound {
            let t = self.gen_range(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.gen_range(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn sample_distinct_unique_and_in_range() {
        let mut r = SplitMix64::new(11);
        let xs = r.sample_distinct(1000, 100);
        assert_eq!(xs.len(), 100);
        let set: std::collections::HashSet<_> = xs.iter().collect();
        assert_eq!(set.len(), 100);
        assert!(xs.iter().all(|&x| x < 1000));
    }

    #[test]
    fn sample_distinct_full_range() {
        let mut r = SplitMix64::new(13);
        let mut xs = r.sample_distinct(32, 32);
        xs.sort_unstable();
        assert_eq!(xs, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
