//! GraphBLAS-semantics query engine on PJRT.
//!
//! RedisGraph's BFS procedure is LAGraph BFS on SuiteSparse:GraphBLAS:
//! level-synchronous masked matrix-vector products over a boolean
//! semiring. This engine runs the same algebra, with the per-step compute
//! AOT-lowered from JAX (Layer 2) whose hot spots are the Pallas kernels
//! (Layer 1):
//!
//! * `bfs_step`:  `next = (frontier ⊕.⊗ A) ⊙ ¬visited` (batched over B
//!   concurrent queries), plus the visited/levels epilogue and an `active`
//!   population count per query so the rust loop can stop without scanning
//!   host-side.
//! * `cc_step`: one Shiloach-Vishkin hook (masked min product — the
//!   GraphBLAS analogue of Figure 2's `remote_min`) plus log₂(N) pointer
//!   jumps, returning the changed count.
//!
//! The engine owns the convergence loops, query batching and timing — the
//! coordinator-side behavior whose Xeon-calibrated cost model lives in
//! [`super::xeon`].

use anyhow::Result;

use crate::graph::csr::Csr;
use crate::runtime::Engine;

/// Result of one batched-BFS evaluation.
#[derive(Debug, Clone)]
pub struct BfsBatchResult {
    /// Per-query levels (graph-sized, -1 = unreached).
    pub levels: Vec<Vec<i64>>,
    /// Step-function invocations executed.
    pub steps: usize,
    /// Host wall time spent in PJRT execution (s).
    pub exec_s: f64,
}

/// Result of one CC evaluation.
#[derive(Debug, Clone)]
pub struct CcResult {
    pub labels: Vec<i64>,
    pub iterations: usize,
    pub exec_s: f64,
}

/// A GraphBLAS-style engine bound to one (small) graph.
pub struct GraphBlasEngine<'e> {
    engine: &'e Engine,
    /// Dense padded adjacency, row-major (n_pad x n_pad).
    adj: Vec<f32>,
    /// Real vertex count.
    n: usize,
    /// Padded dimension (the artifact's n).
    n_pad: usize,
}

impl std::fmt::Debug for GraphBlasEngine<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GraphBlasEngine")
            .field("n", &self.n)
            .field("n_pad", &self.n_pad)
            .finish()
    }
}

impl<'e> GraphBlasEngine<'e> {
    /// Embed graph `g` into the engine's padded adjacency. Fails if the
    /// graph exceeds the artifact dimension.
    pub fn new(engine: &'e Engine, g: &Csr) -> Result<Self> {
        let n_pad = engine.manifest().n;
        anyhow::ensure!(
            g.n() <= n_pad,
            "graph has {} vertices but artifacts were lowered at n={n_pad}; \
             regenerate with `make artifacts N={}` or use a smaller graph",
            g.n(),
            g.n().next_power_of_two()
        );
        let mut adj = vec![0.0f32; n_pad * n_pad];
        for u in 0..g.n() as u32 {
            for &v in g.neighbors(u) {
                adj[u as usize * n_pad + v as usize] = 1.0;
            }
        }
        Ok(GraphBlasEngine { engine, adj, n: g.n(), n_pad })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Run BFS for up to `batch-variant` sources simultaneously, chunking
    /// if more sources than the largest lowered batch.
    pub fn bfs(&self, sources: &[u32]) -> Result<BfsBatchResult> {
        anyhow::ensure!(!sources.is_empty(), "need at least one source");
        let mut levels = Vec::with_capacity(sources.len());
        let mut steps = 0usize;
        let mut exec_s = 0.0f64;
        // Chunk over the largest available batch variant.
        let max_b = self
            .engine
            .manifest()
            .bfs_batches()
            .last()
            .copied()
            .ok_or_else(|| anyhow::anyhow!("no bfs_step artifacts"))?;
        for chunk in sources.chunks(max_b) {
            let r = self.bfs_chunk(chunk)?;
            levels.extend(r.levels);
            steps += r.steps;
            exec_s += r.exec_s;
        }
        Ok(BfsBatchResult { levels, steps, exec_s })
    }

    fn bfs_chunk(&self, sources: &[u32]) -> Result<BfsBatchResult> {
        let variant = self
            .engine
            .manifest()
            .bfs_variant_for(sources.len())
            .ok_or_else(|| anyhow::anyhow!("no bfs_step artifacts"))?
            .clone();
        let b = variant.batch;
        let n = self.n_pad;
        debug_assert!(sources.len() <= b);

        let mut frontier = vec![0.0f32; b * n];
        let mut visited = vec![0.0f32; b * n];
        let mut levels = vec![-1.0f32; b * n];
        for (q, &src) in sources.iter().enumerate() {
            anyhow::ensure!((src as usize) < self.n, "source {src} out of range");
            frontier[q * n + src as usize] = 1.0;
            visited[q * n + src as usize] = 1.0;
            levels[q * n + src as usize] = 0.0;
        }

        let mut steps = 0usize;
        let mut exec_s = 0.0f64;
        let mut depth = 1.0f32;
        loop {
            let t0 = std::time::Instant::now();
            let out = self.engine.execute_f32(
                &variant.name,
                &[
                    (&self.adj, &[n as i64, n as i64]),
                    (&frontier, &[b as i64, n as i64]),
                    (&visited, &[b as i64, n as i64]),
                    (&levels, &[b as i64, n as i64]),
                    (&[depth], &[]),
                ],
            )?;
            exec_s += t0.elapsed().as_secs_f64();
            steps += 1;
            let [next, vis, lev, active]: [Vec<f32>; 4] =
                out.try_into().map_err(|_| anyhow::anyhow!("bad output arity"))?;
            frontier = next;
            visited = vis;
            levels = lev;
            depth += 1.0;
            if active[..sources.len()].iter().all(|&a| a == 0.0) {
                break;
            }
            anyhow::ensure!(
                (steps as usize) <= self.n + 1,
                "BFS failed to converge in {} steps",
                steps
            );
        }

        let out_levels = sources
            .iter()
            .enumerate()
            .map(|(q, _)| {
                levels[q * n..q * n + self.n].iter().map(|&x| x as i64).collect()
            })
            .collect();
        Ok(BfsBatchResult { levels: out_levels, steps, exec_s })
    }

    /// Run connected components to convergence.
    pub fn cc(&self) -> Result<CcResult> {
        let variant = self
            .engine
            .manifest()
            .cc_variant()
            .ok_or_else(|| anyhow::anyhow!("no cc_step artifact"))?
            .clone();
        let n = self.n_pad;
        let mut labels: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut iterations = 0usize;
        let mut exec_s = 0.0f64;
        loop {
            let t0 = std::time::Instant::now();
            let out = self.engine.execute_f32(
                &variant.name,
                &[(&self.adj, &[n as i64, n as i64]), (&labels, &[n as i64])],
            )?;
            exec_s += t0.elapsed().as_secs_f64();
            iterations += 1;
            let changed = out[1][0];
            labels = out[0].clone();
            if changed == 0.0 {
                break;
            }
            anyhow::ensure!(
                iterations <= self.n + 1,
                "CC failed to converge in {iterations} iterations"
            );
        }
        Ok(CcResult {
            labels: labels[..self.n].iter().map(|&x| x as i64).collect(),
            iterations,
            exec_s,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::oracle;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::rmat::Rmat;
    use crate::runtime::artifact::default_artifacts_dir;

    fn engine() -> Option<Engine> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        match Engine::from_dir(&dir) {
            Ok(eng) => Some(eng),
            // A build without the `pjrt` feature gets the stub engine,
            // whose constructor refuses: skip like missing artifacts.
            Err(e) => {
                eprintln!("skipping: {e}");
                None
            }
        }
    }

    fn small_rmat(engine: &Engine) -> Csr {
        // Fit comfortably inside the artifact dimension.
        let scale = (engine.manifest().n as f64).log2() as u32 - 1;
        let mut cfg = GraphConfig::with_scale(scale);
        cfg.seed = 99;
        let r = Rmat::new(cfg);
        build_undirected_csr(1 << scale, &r.edges())
    }

    #[test]
    fn bfs_matches_oracle() {
        let Some(eng) = engine() else { return };
        let g = small_rmat(&eng);
        let gb = GraphBlasEngine::new(&eng, &g).unwrap();
        let sources = [1u32, 7, 23];
        let res = gb.bfs(&sources).unwrap();
        assert_eq!(res.levels.len(), 3);
        for (i, &src) in sources.iter().enumerate() {
            oracle::check_bfs(&g, src, &res.levels[i]).unwrap();
        }
        assert!(res.exec_s > 0.0);
    }

    #[test]
    fn cc_matches_oracle() {
        let Some(eng) = engine() else { return };
        let g = small_rmat(&eng);
        let gb = GraphBlasEngine::new(&eng, &g).unwrap();
        let res = gb.cc().unwrap();
        oracle::check_cc(&g, &res.labels).unwrap();
        assert!(res.iterations >= 1);
    }

    #[test]
    fn oversized_graph_rejected() {
        let Some(eng) = engine() else { return };
        let n = eng.manifest().n;
        let edges: Vec<(u32, u32)> = (0..n as u32).map(|i| (i, i + 1)).collect();
        let g = build_undirected_csr(n + 2, &edges);
        let err = GraphBlasEngine::new(&eng, &g).unwrap_err();
        assert!(err.to_string().contains("lowered at"));
    }

    #[test]
    fn batch_chunking_handles_many_sources() {
        let Some(eng) = engine() else { return };
        let g = small_rmat(&eng);
        let gb = GraphBlasEngine::new(&eng, &g).unwrap();
        let max_b = *eng.manifest().bfs_batches().last().unwrap();
        let k = max_b + 3; // forces two chunks
        let sources: Vec<u32> = (0..k as u32).collect();
        let res = gb.bfs(&sources).unwrap();
        assert_eq!(res.levels.len(), k);
        oracle::check_bfs(&g, 0, &res.levels[0]).unwrap();
        oracle::check_bfs(&g, max_b as u32, &res.levels[max_b]).unwrap();
    }
}
