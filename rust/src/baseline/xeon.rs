//! Calibrated RedisGraph-on-Xeon timing model (paper §IV-D).
//!
//! We cannot rent the paper's x1e.32xlarge + Redis Enterprise setup, so
//! Table III's RedisGraph column is reproduced by a model with two factors:
//!
//! * a **base rate**: the per-query service time of RedisGraph's
//!   GraphBLAS BFS at the paper's graph size. Our PJRT engine
//!   ([`super::engine`]) measures the same algebra end-to-end at artifact
//!   scale; `anchor_measured` rescales the model to such a measurement so
//!   the whole column can be regenerated from an actual execution.
//! * a **contention curve**: per-query slow-down as a function of
//!   concurrent queries on a 64-core / 128-hardware-thread box —
//!   memory-bandwidth contention up to ~32 queries, hyper-thread sharing
//!   to 128, and preemptive oversubscription past the hardware thread
//!   count ("some of the threads will be preempted for other tasks like
//!   keeping the client-server connections alive"). The curve's knots are
//!   calibrated once against the published Table III column (that table is
//!   the only ground truth available for this machine) and interpolated
//!   log-linearly elsewhere, so the model also predicts query counts the
//!   paper did not measure.

/// Per-query contention factor knots: (concurrent queries, slow-down).
/// Derived from the paper's Table III RedisGraph row divided by q x t(1).
const CONTENTION_KNOTS: &[(f64, f64)] = &[
    (1.0, 1.0),
    (8.0, 1.0),
    (16.0, 1.74),
    (32.0, 1.73),
    (64.0, 1.91),
    (128.0, 2.67),
];

/// Growth exponent applied beyond the last knot (oversubscription past the
/// machine's 128 hardware threads: preemption grows the per-query cost
/// roughly linearly in q).
const OVERSUB_EXPONENT: f64 = 1.0;

/// The Xeon/RedisGraph cost model.
#[derive(Debug, Clone)]
pub struct XeonModel {
    /// Service time of one isolated BFS query (s), client overhead
    /// excluded. Paper anchor: t(1) = 5 s on the scale-25 graph.
    pub base_query_s: f64,
    /// Hardware threads (128 vCPUs on the x1e.32xlarge).
    pub hw_threads: usize,
}

impl XeonModel {
    /// The paper's configuration: scale-25 graph, 5 s single query.
    pub fn paper() -> Self {
        XeonModel { base_query_s: 5.0, hw_threads: 128 }
    }

    /// Anchor the model to a measured single-query time of our PJRT
    /// GraphBLAS engine, scaled from artifact-sized graph to the target
    /// graph by directed edge count (SpMV work is O(m) per level sweep and
    /// level count grows slowly).
    pub fn anchor_measured(measured_s: f64, measured_m: usize, target_m: usize) -> Self {
        assert!(measured_s > 0.0 && measured_m > 0);
        XeonModel {
            base_query_s: measured_s * target_m as f64 / measured_m as f64,
            hw_threads: 128,
        }
    }

    /// Per-query contention factor at `q` concurrent queries.
    pub fn contention(&self, q: usize) -> f64 {
        let q = q.max(1) as f64;
        let knots = CONTENTION_KNOTS;
        if q <= knots[0].0 {
            return knots[0].1;
        }
        for w in knots.windows(2) {
            let (q0, c0) = w[0];
            let (q1, c1) = w[1];
            if q <= q1 {
                // Log-linear interpolation in q.
                let f = (q.ln() - q0.ln()) / (q1.ln() - q0.ln());
                return c0 + f * (c1 - c0);
            }
        }
        // Beyond the last knot: preemptive oversubscription.
        let (q_last, c_last) = *knots.last().unwrap();
        c_last * (q / q_last).powf(OVERSUB_EXPONENT)
    }

    /// Total wall time for `q` concurrent BFS queries (s), Table III row.
    pub fn total_s(&self, q: usize) -> f64 {
        q as f64 * self.base_query_s * self.contention(q)
    }

    /// Mean per-query service time at concurrency `q` (s).
    pub fn per_query_s(&self, q: usize) -> f64 {
        self.base_query_s * self.contention(q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_table3_redisgraph_row() {
        let m = XeonModel::paper();
        // Paper row: 5, 40, 139, 276, 610, 1707 (s).
        let expect = [(1, 5.0), (8, 40.0), (16, 139.0), (32, 276.0), (64, 610.0), (128, 1707.0)];
        for (q, t) in expect {
            let got = m.total_s(q);
            assert!(
                (got - t).abs() / t < 0.02,
                "q={q}: modeled {got:.1}s vs paper {t}s"
            );
        }
    }

    #[test]
    fn contention_monotone_after_warmup() {
        let m = XeonModel::paper();
        assert!(m.contention(1) <= m.contention(16) + 1e-9);
        assert!(m.contention(64) < m.contention(128));
        // Past the hardware threads it keeps degrading.
        assert!(m.contention(256) > 1.5 * m.contention(128) * 0.9);
    }

    #[test]
    fn interpolates_between_knots() {
        let m = XeonModel::paper();
        let c12 = m.contention(12);
        assert!(c12 > m.contention(8) && c12 < m.contention(16));
    }

    #[test]
    fn anchoring_scales_by_edges() {
        let m = XeonModel::anchor_measured(0.01, 10_000, 1_000_000);
        assert!((m.base_query_s - 1.0).abs() < 1e-12);
        // Shape identical to the paper model.
        let p = XeonModel::paper();
        for q in [1usize, 16, 128] {
            let ratio_m = m.total_s(q) / m.total_s(1);
            let ratio_p = p.total_s(q) / p.total_s(1);
            assert!((ratio_m - ratio_p).abs() < 1e-9);
        }
    }
}
