//! The comparison platform of §IV-D: RedisGraph (GraphBLAS/LAGraph on Intel
//! Xeon), rebuilt in three parts:
//!
//! * [`engine`] — a *real, executing* GraphBLAS-semantics engine: BFS as
//!   masked boolean SpMV and SV-CC as a masked min product, AOT-compiled
//!   from JAX+Pallas and run on PJRT (this is exactly how RedisGraph
//!   implements its BFS procedure on top of GraphBLAS [17]).
//! * [`xeon`] — the calibrated timing model mapping the engine's workload
//!   to the paper's x1e.32xlarge (128 vCPU Xeon) behavior, including the
//!   thread-pool oversubscription that makes 128 concurrent queries blow
//!   up (Table III's super-linear last column).
//! * [`redisgraph`] — the client-facing bits: the Figure-5 Cypher query
//!   template and the `redis_cli` client/server overhead adjustment the
//!   paper applies to Pathfinder times.

pub mod engine;
pub mod redisgraph;
pub mod xeon;

pub use engine::GraphBlasEngine;
pub use redisgraph::{adjusted_speedup, query_template, ClientOverhead};
pub use xeon::XeonModel;
