//! RedisGraph client-facing pieces: the Figure-5 query and the `redis_cli`
//! overhead adjustment.

/// The paper's Figure 5: the Cypher query issued per BFS, with `{src}`
/// standing for the source vertex id.
pub const QUERY_TEMPLATE: &str = "GRAPH.QUERY g \"MATCH (n) WHERE id(n) = {src} \
CALL algo.BFS(n, 0, NULL) YIELD nodes RETURN count(nodes)\"";

/// Render the Figure-5 query for a concrete source vertex.
pub fn query_template(src: u32) -> String {
    QUERY_TEMPLATE.replace("{src}", &src.to_string())
}

/// The client/server overhead adjustment of §IV-D.
///
/// "Our assumption is that the single redis_cli instance provides a
/// reasonable approximation to the overhead, and we add that to all the
/// Pathfinder results when computing time ratios." Working Table III
/// backwards confirms the added constant equals the single-query
/// RedisGraph total (5 s): e.g. 1707 / (84.04 + 5) = 19.2.
#[derive(Debug, Clone, Copy)]
pub struct ClientOverhead {
    /// Seconds added to every Pathfinder measurement.
    pub overhead_s: f64,
}

impl ClientOverhead {
    /// Overhead approximated by the modeled single-client RedisGraph time.
    pub fn from_single_query(rg_single_s: f64) -> Self {
        ClientOverhead { overhead_s: rg_single_s }
    }

    /// Pathfinder time adjusted for client/server overhead.
    pub fn adjust(&self, pathfinder_s: f64) -> f64 {
        pathfinder_s + self.overhead_s
    }
}

/// The paper's "adjusted speed-up": RedisGraph time over overhead-adjusted
/// Pathfinder time.
pub fn adjusted_speedup(rg_s: f64, pathfinder_s: f64, overhead: ClientOverhead) -> f64 {
    rg_s / overhead.adjust(pathfinder_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_renders_source() {
        let q = query_template(12345);
        assert!(q.contains("id(n) = 12345"));
        assert!(q.contains("algo.BFS"));
    }

    #[test]
    fn reproduces_paper_adjusted_speedups() {
        let ov = ClientOverhead::from_single_query(5.0);
        // Table III, 32-node row: 1707 s RG vs 84.04 s PF at 128 queries.
        let s = adjusted_speedup(1707.0, 84.04, ov);
        assert!((s - 19.2).abs() < 0.1, "{s}");
        // 8-node row at 128: 1707 / (226.30 + 5) = 7.38.
        let s = adjusted_speedup(1707.0, 226.30, ov);
        assert!((s - 7.38).abs() < 0.02, "{s}");
        // Single query, 8 nodes: 5 / (3.47 + 5) = 0.59 — RedisGraph WINS.
        let s = adjusted_speedup(5.0, 3.47, ov);
        assert!((s - 0.59).abs() < 0.01, "{s}");
    }
}
