//! `pathfinder` — the Layer-3 launcher: generate graphs, run and serve
//! concurrent analyses on the simulated Lucata Pathfinder, and regenerate
//! every table and figure of the paper's evaluation.
//!
//! ```text
//! pathfinder generate   [--scale N] [--edge-factor F] [--seed S] --out g.csr
//! pathfinder inspect    --graph g.csr | [--scale N]
//! pathfinder validate   [--scale N] [--queries K]   — every registered
//!                       analysis (bfs, cc, sssp, khop, pagerank, tricount)
//!                       vs its host oracle
//! pathfinder run        [--scale N] --machine pathfinder-8
//!                       [--analysis bfs=16,cc=4,sssp=8]   (any registry
//!                       label; `label` alone means count 1; default bfs=16.
//!                       The old --bfs/--cc/--sssp/--khop/--pagerank/
//!                       --tricount flags still work as deprecated aliases)
//!                       [--khop-k HOPS]   (deprecated: re-registers khop)
//!                       [--policy sequential|concurrent|queue|reject|shed]
//!                       [--max-waiting W]
//!                       [--weights interactive=4,standard=2,batch=1] [--preempt]
//!                       [--trace out.json[,sample=NS]]   (Chrome trace +
//!                                      telemetry sidecar; see serve --trace)
//! pathfinder serve      [--scale N] --machine NAME [--queries K] [--rate Q/S]
//!                       [--mix bfs=0.7,cc=0.1,pagerank=0.1,tricount=0.1]
//!                       [--on-full queue|reject|shed] [--max-waiting W]
//!                       [--priority-mix interactive=0.2,standard=0.6,batch=0.2]
//!                       [--slo khop=0.05,bfs=0.2]   (per-class p99 targets, s)
//!                       [--weights interactive=4,standard=2,batch=1]
//!                       [--preempt]   (park Batch at checkpoints under
//!                                      Interactive pressure)
//!                       [--mutate rate=R,batch=B[,delete=F][,compact=K]]
//!                                     (live edge ingest: update batches as
//!                                      Batch-class work; queries pin their
//!                                      admission epoch)
//!                       [--fleet nodes=N[,replicas=R][,partition=hash|balanced]]
//!                                     (sharded multi-chassis fleet: the graph
//!                                      partitioned across N shards x R replicas,
//!                                      cross-shard traffic priced on the fleet
//!                                      interconnect)
//!                       [--batch [width=W,window=T]]
//!                                     (fuse compatible same-epoch queries into
//!                                      one multi-source sweep; width <= 64
//!                                      sources per fused query, window in
//!                                      seconds; bare --batch = width=16,
//!                                      window=0.001)
//!                       [--trace out.json[,sample=NS]]
//!                                     (record every scheduling event: writes
//!                                      Perfetto-openable Chrome trace JSON to
//!                                      the path plus machine-readable
//!                                      <stem>.telemetry.json beside it;
//!                                      sample = telemetry interval in
//!                                      simulated ns, default auto)
//!                       [--scenario <name|file.json>]
//!                                     (open-loop multi-tenant load scenario:
//!                                      a catalog name — steady, diurnal,
//!                                      burst, overload-ramp,
//!                                      multi-tenant-contention — or a
//!                                      ScenarioSpec JSON file; replaces
//!                                      --queries/--rate/--mix/--priority-mix
//!                                      with per-stream arrival processes;
//!                                      see docs/SCENARIOS.md)
//!                       [--scenario-compress F]
//!                                     (play the scenario F× faster: rates ×F,
//!                                      duration ÷F — same expected arrivals,
//!                                      F× the instantaneous load)
//!                       [--report-json out.json]
//!                                     (write the machine-readable service
//!                                      report: counts, per-class latency,
//!                                      SLO verdicts, per-stream stats, and a
//!                                      BENCH schema-2 class_matrix row)
//! pathfinder experiment fig3|fig4|table1|table2|table3|scaling|ablation|all
//!                       [--scale N] [--results DIR] [--config cfg.json]
//!                       [--measure-baseline] [--artifacts DIR]
//! pathfinder calibrate  [--scale N]
//! pathfinder config     --out cfg.json [--scale N]   — dump an editable
//!                       experiment config (machines, workload, mixes)
//! pathfinder baseline   [--sources K] — run the PJRT GraphBLAS engine
//! ```

use anyhow::{bail, Context, Result};

use pathfinder_queries::alg::{Analysis, AnalysisRegistry};
use pathfinder_queries::bench_harness::{
    ablation, calibrate, fig3, fig4, scaling, table1, table2, table3, Harness,
};
use pathfinder_queries::config::experiment::ExperimentConfig;
use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::scenario::ScenarioSpec;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::coordinator::{
    planner, telemetry, BatchConfig, Coordinator, FleetConfig, GraphService, MutationConfig,
    Policy, PreemptPolicy, PriorityMix, QueryRequest, ServiceConfig, ShareWeights, TraceSpec,
    WorkloadSpec,
};
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::csr::Csr;
use pathfinder_queries::graph::rmat::Rmat;
use pathfinder_queries::graph::{io, validate};
use pathfinder_queries::runtime::artifact::default_artifacts_dir;
use pathfinder_queries::runtime::Engine;
use pathfinder_queries::sim::flow::OnFull;
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::util::cli::Args;

fn main() {
    if let Err(e) = run(Args::from_env().unwrap_or_default()) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: Args) -> Result<()> {
    match args.subcommand() {
        Some("generate") => cmd_generate(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("validate") => cmd_validate(&args),
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("baseline") => cmd_baseline(&args),
        Some("config") => cmd_config(&args),
        Some(other) => bail!("unknown subcommand {other:?} (try --help)"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!("{}", include_str!("main.rs").lines().skip(1).take_while(|l| l.starts_with("//!")).map(|l| l.trim_start_matches("//!").trim_start()).collect::<Vec<_>>().join("\n"));
}

/// Graph shared by the subcommands: `--graph file.csr` loads, otherwise
/// generate from `--scale` / `--edge-factor` / `--seed`.
fn load_or_generate(args: &Args) -> Result<Csr> {
    if let Some(path) = args.opt("graph") {
        return io::load_csr(std::path::Path::new(path));
    }
    let cfg = graph_config(args)?;
    eprintln!(
        "generating R-MAT scale {} edge-factor {} (seed {})...",
        cfg.scale, cfg.edge_factor, cfg.seed
    );
    let rmat = Rmat::new(cfg.clone());
    Ok(build_undirected_csr(cfg.n_vertices() as usize, &rmat.edges()))
}

fn graph_config(args: &Args) -> Result<GraphConfig> {
    let mut cfg = GraphConfig::default();
    cfg.scale = args.opt_parse_or("scale", cfg.scale)?;
    cfg.edge_factor = args.opt_parse_or("edge-factor", cfg.edge_factor)?;
    cfg.seed = args.opt_parse_or("seed", cfg.seed)?;
    cfg.validate()?;
    Ok(cfg)
}

fn machine_config(args: &Args) -> Result<MachineConfig> {
    let name = args.opt_or("machine", "pathfinder-8");
    if let Some(m) = MachineConfig::preset(&name) {
        return Ok(m);
    }
    // Not a preset: treat as a JSON machine-config path.
    MachineConfig::from_file(std::path::Path::new(&name))
        .with_context(|| format!("{name:?} is neither a preset nor a readable config file"))
}

fn cmd_generate(args: &Args) -> Result<()> {
    let out = args.opt("out").context("generate needs --out FILE")?;
    let g = load_or_generate(args)?;
    io::save_csr(&g, std::path::Path::new(out))?;
    let r = validate::report(&g);
    println!(
        "wrote {out}: {} vertices, {} directed edges, max degree {}, {} components",
        r.n, r.m_directed, r.max_degree, r.components
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let g = load_or_generate(args)?;
    validate::check_invariants(&g)?;
    let r = validate::report(&g);
    println!("vertices            {}", r.n);
    println!("directed edges      {}", r.m_directed);
    println!("undirected edges    {}", r.m_undirected);
    println!("max degree          {}", r.max_degree);
    println!("mean degree         {:.2}", r.mean_degree);
    println!("isolated vertices   {}", r.isolated_vertices);
    println!("components          {}", r.components);
    println!("largest component   {}", r.largest_component);
    Ok(())
}

/// Cross-validate the whole stack at small scale: every registered
/// analysis vs its host oracle, plus (if artifacts exist) the PJRT
/// GraphBLAS engine.
fn cmd_validate(args: &Args) -> Result<()> {
    let g = load_or_generate(args)?;
    let k: usize = args.opt_parse_or("queries", 8)?;
    let machine = Machine::new(machine_config(args)?);
    let registry = AnalysisRegistry::builtin();

    println!(
        "validating {} on {} vertices...",
        registry.labels().join(" + "),
        g.n()
    );
    let srcs = pathfinder_queries::graph::sample::bfs_sources(&g, k, 7);
    for label in registry.labels() {
        // One instance per source, deduplicated by description — a
        // source-free analysis (cc) collapses to a single instance,
        // sourced ones validate at every source and stripe offset.
        let mut instances = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for &s in &srcs {
            let a = registry.build(label, s)?;
            if seen.insert(a.describe()) {
                instances.push(a);
            }
        }
        for (i, a) in instances.iter().enumerate() {
            let out = a.run_offset(g.view(), &machine, i);
            a.validate(g.view(), &out.values)
                .with_context(|| format!("{} failed validation", a.describe()))?;
        }
        println!("  {label}: {} instance(s) match the host oracle", instances.len());
    }

    let dir = args
        .opt("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    if dir.join("manifest.json").exists() {
        match Engine::from_dir(&dir) {
            Ok(eng) => {
                let n_art = eng.manifest().n;
                if g.n() <= n_art {
                    let gb = pathfinder_queries::baseline::GraphBlasEngine::new(&eng, &g)?;
                    let res = gb.bfs(&srcs)?;
                    for (i, &src) in srcs.iter().enumerate() {
                        pathfinder_queries::alg::oracle::check_bfs(&g, src, &res.levels[i])?;
                    }
                    let cc = gb.cc()?;
                    pathfinder_queries::alg::oracle::check_cc(&g, &cc.labels)?;
                    println!("  PJRT GraphBLAS engine matches host oracles");
                } else {
                    println!("  (graph larger than artifact n={n_art}; baseline check skipped)");
                }
            }
            Err(e) => println!("  (baseline check skipped: {e})"),
        }
    } else {
        println!("  (no artifacts at {dir:?}; baseline check skipped)");
    }
    println!("OK");
    Ok(())
}

/// Parse `--analysis <label>[=count][,...]`: any registry label, count
/// defaulting to 1 when omitted.
fn parse_analysis_spec(spec: &str) -> Result<Vec<(String, usize)>> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (label, count) = match part.split_once('=') {
            Some((l, c)) => {
                let count: usize = c
                    .trim()
                    .parse()
                    .with_context(|| format!("--analysis {l}: bad count {c:?}"))?;
                (l.trim().to_string(), count)
            }
            None => (part.to_string(), 1),
        };
        anyhow::ensure!(count > 0, "--analysis {label}: count must be positive");
        out.push((label, count));
    }
    anyhow::ensure!(!out.is_empty(), "--analysis: empty spec");
    Ok(out)
}

/// Per-class source seed. The named cases reproduce the seeds the old
/// per-analysis flags used, so the deprecated aliases (and any script
/// built on them) see the exact same query streams; other labels fork
/// by label hash so two sourced classes never share sources.
fn label_seed(label: &str, seed: u64) -> u64 {
    match label {
        "bfs" => seed,
        "sssp" => seed ^ 0x55,
        "khop" => seed ^ 0xAA,
        _ => {
            let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a
            for b in label.bytes() {
                h = (h ^ b as u64).wrapping_mul(0x0100_0000_01b3);
            }
            seed ^ h
        }
    }
}

const DEPRECATED_RUN_FLAGS: [&str; 6] = ["bfs", "cc", "sssp", "khop", "pagerank", "tricount"];

fn cmd_run(args: &Args) -> Result<()> {
    let g = load_or_generate(args)?;
    let machine = Machine::new(machine_config(args)?);
    let coord = Coordinator::new(&g, machine);
    let seed: u64 = args.opt_parse_or("query-seed", 0xBF5)?;

    let mut registry = AnalysisRegistry::builtin();
    let khop_k: u32 = args.opt_parse_or("khop-k", 2)?;
    if khop_k != 2 {
        // Deprecated-compat knob: analysis parameters belong to registry
        // factories, so honor it by re-registering the khop factory.
        eprintln!("warning: --khop-k is deprecated; register a khop factory instead");
        registry.register("khop", std::sync::Arc::new(move |src| -> std::sync::Arc<
            dyn Analysis,
        > {
            std::sync::Arc::new(pathfinder_queries::alg::KHop::new(src, khop_k))
        }));
    }

    // Registry-driven workload: `--analysis <label>[=count][,...]`. The
    // old per-analysis flag zoo still works as deprecated aliases that
    // translate onto the same spec (bfs keeps its historical default of
    // 16 so `run --cc 4` still means 16 bfs + 4 cc).
    let spec: Vec<(String, usize)> = match args.opt("analysis") {
        Some(s) => {
            for flag in DEPRECATED_RUN_FLAGS {
                anyhow::ensure!(
                    args.opt(flag).is_none(),
                    "--analysis and the deprecated --{flag} flag are mutually exclusive"
                );
            }
            parse_analysis_spec(s)?
        }
        None => {
            let used: Vec<&str> = DEPRECATED_RUN_FLAGS
                .into_iter()
                .filter(|f| args.opt(f).is_some())
                .collect();
            if !used.is_empty() {
                eprintln!(
                    "warning: --{} deprecated; use --analysis {}",
                    used.join("/--"),
                    used.iter().map(|f| format!("{f}=N")).collect::<Vec<_>>().join(",")
                );
            }
            let mut counts = vec![("bfs".to_string(), args.opt_parse_or("bfs", 16)?)];
            for flag in &DEPRECATED_RUN_FLAGS[1..] {
                counts.push((flag.to_string(), args.opt_parse_or(flag, 0)?));
            }
            counts.retain(|(_, c)| *c > 0);
            counts
        }
    };
    anyhow::ensure!(!spec.is_empty(), "nothing to run: all class counts are zero");

    // One list per class, interleaved into a mixed submission stream.
    let mut classes: Vec<Vec<QueryRequest>> = Vec::new();
    for (label, count) in &spec {
        classes.push(
            planner::registry_queries(&g, &registry, label, *count, label_seed(label, seed))
                .with_context(|| format!("known analyses: {}", registry.labels().join(", ")))?,
        );
    }
    let queries = planner::interleave_classes(classes);

    // Fair-share weights + checkpoint preemption: admitted policies only
    // (sequential runs one query at a time; raw concurrent has no
    // scheduler to enforce either).
    let weights = match args.opt("weights") {
        Some(spec) => ShareWeights::parse(spec)?,
        None => ShareWeights::flat(),
    };
    let preempt = args.has_flag("preempt").then(PreemptPolicy::default);
    let policy = match args.opt_or("policy", "concurrent").as_str() {
        "sequential" => Policy::Sequential,
        "concurrent" => Policy::Concurrent,
        "queue" => Policy::ConcurrentAdmitted { on_full: OnFull::Queue, weights, preempt },
        "reject" => Policy::ConcurrentAdmitted { on_full: OnFull::Reject, weights, preempt },
        "shed" => Policy::ConcurrentAdmitted {
            on_full: OnFull::Shed { max_waiting: args.opt_parse_or("max-waiting", 64)? },
            weights,
            preempt,
        },
        other => bail!("unknown policy {other:?}"),
    };
    if matches!(policy, Policy::Sequential | Policy::Concurrent)
        && (!weights.is_flat() || preempt.is_some())
    {
        bail!("--weights/--preempt need an admitted policy (--policy queue|reject|shed)");
    }

    let trace = args.opt("trace").map(TraceSpec::parse).transpose()?;
    let rep = match &trace {
        Some(tspec) => {
            let mut buf = pathfinder_queries::sim::trace::TraceBuffer::new();
            let specs = coord.prepare(coord.view(), 0, &queries, 0);
            let identity: Vec<usize> = (0..queries.len()).collect();
            let rep = coord
                .run_specs_grouped_traced(&queries, &identity, &queries, &specs, policy, &mut buf)?;
            let m = &coord.machine().cfg;
            let tcfg = telemetry::TelemetryConfig::default()
                .with_sample_ns(tspec.sample_ns)
                .with_chassis(m.nodes_per_chassis, m.nodes);
            telemetry::export(&buf, &tcfg, &tspec.path)?;
            rep
        }
        None => coord.run(&queries, policy)?,
    };
    let desc: Vec<String> = spec.iter().map(|(l, c)| format!("{c} {l}")).collect();
    println!(
        "{} on {}: {} queries ({})",
        rep.policy,
        rep.machine,
        queries.len(),
        desc.join(" + ")
    );
    println!("  makespan            {:.4} s", rep.makespan_s);
    println!(
        "  completed/rejected/shed/preempted  {}/{}/{}/{}",
        rep.completed(),
        rep.rejections(),
        rep.sheds(),
        rep.preempted()
    );
    match rep.mean_latency_s() {
        Some(s) => println!("  mean latency        {s:.4} s"),
        None => println!("  mean latency        n/a (nothing completed)"),
    }
    println!("  throughput          {:.2} q/s", rep.throughput_qps());
    println!("  peak concurrency    {}", rep.peak_concurrency);
    println!("  channel utilization {:.0}%", rep.mean_channel_utilization * 100.0);
    for (label, q) in rep.per_class_quantiles() {
        println!("  {label:>5} latency (s)   {}", q.latency_line());
    }
    for s in rep.priority_stats() {
        println!("  {}", s.line());
    }
    if let Some(tspec) = &trace {
        println!(
            "  trace               {} (+ {})",
            tspec.path.display(),
            telemetry::telemetry_path(&tspec.path).display()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let g = load_or_generate(args)?;
    let machine = Machine::new(machine_config(args)?);
    let svc = GraphService::new(&g, machine);
    let registry = AnalysisRegistry::builtin();
    anyhow::ensure!(
        args.opt("cc-fraction").is_none(),
        "--cc-fraction was replaced by the declarative mix spec; \
         use e.g. --mix bfs=0.9,cc=0.1"
    );
    let mut workload = WorkloadSpec::parse(&args.opt_or("mix", "bfs=0.9,cc=0.1"), &registry)?;
    // Per-class p99 SLO targets: `--slo khop=0.05,bfs=0.2` (seconds).
    if let Some(slo_spec) = args.opt("slo") {
        for (label, target) in pathfinder_queries::util::cli::parse_kv_f64_list(slo_spec, "SLO")?
        {
            let class = workload
                .classes
                .iter_mut()
                .find(|c| c.label == label)
                .ok_or_else(|| anyhow::anyhow!("--slo names unknown class {label:?}"))?;
            class.slo_p99_s = Some(target);
        }
    }
    let cfg = ServiceConfig {
        queries: args.opt_parse_or("queries", 256)?,
        arrival_rate_per_s: args.opt_parse_or("rate", 100.0)?,
        workload,
        on_full: match args.opt_or("on-full", "queue").as_str() {
            "queue" => OnFull::Queue,
            "reject" => OnFull::Reject,
            "shed" => OnFull::Shed { max_waiting: args.opt_parse_or("max-waiting", 64)? },
            other => bail!("unknown --on-full {other:?}"),
        },
        priority_mix: args.opt("priority-mix").map(PriorityMix::parse).transpose()?,
        weights: match args.opt("weights") {
            Some(spec) => ShareWeights::parse(spec)?,
            None => ShareWeights::flat(),
        },
        preempt: args.has_flag("preempt").then(PreemptPolicy::default),
        mutation: args.opt("mutate").map(MutationConfig::parse).transpose()?,
        fleet: args.opt("fleet").map(FleetConfig::parse).transpose()?,
        // `--batch width=16,window=0.001` or bare `--batch` for defaults.
        batch: match args.opt("batch") {
            Some(spec) => Some(BatchConfig::parse(spec)?),
            None if args.has_flag("batch") => Some(BatchConfig::default()),
            None => None,
        },
        trace: args.opt("trace").map(TraceSpec::parse).transpose()?,
        scenario: match args.opt("scenario") {
            Some(arg) => {
                let spec = ScenarioSpec::load(arg)?;
                match args.opt_parse::<f64>("scenario-compress")? {
                    Some(f) => Some(spec.time_compressed(f)?),
                    None => Some(spec),
                }
            }
            None => {
                anyhow::ensure!(
                    args.opt("scenario-compress").is_none(),
                    "--scenario-compress needs --scenario"
                );
                None
            }
        },
        seed: args.opt_parse_or("seed", 0x5E21)?,
    };
    let mix_desc: Vec<String> = cfg
        .workload
        .classes
        .iter()
        .map(|c| format!("{}={:.2}", c.label, c.weight))
        .collect();
    let mutate_desc = match &cfg.mutation {
        Some(m) => format!(", mutating at {}", m.label()),
        None => String::new(),
    };
    let fleet_desc = match &cfg.fleet {
        Some(f) => format!(", fleet {}", f.label()),
        None => String::new(),
    };
    let batch_desc = match &cfg.batch {
        Some(b) => format!(", batching {}", b.label()),
        None => String::new(),
    };
    match &cfg.scenario {
        Some(spec) => {
            let streams: Vec<String> = spec
                .streams
                .iter()
                .map(|s| format!("{} {}", s.name, s.process.label()))
                .collect();
            println!(
                "serving scenario {:?} over {:.3}s — {} expected arrivals [{}] on {} \
                 (seed {:#x}){}{}{}...",
                spec.name,
                spec.duration_s,
                spec.expected_arrivals().round() as u64,
                streams.join("; "),
                svc.coordinator().machine().cfg.name,
                cfg.seed,
                mutate_desc,
                fleet_desc,
                batch_desc
            );
        }
        None => println!(
            "serving {} queries at {:.0} q/s ({}) on {} (seed {:#x}){}{}{}...",
            cfg.queries,
            cfg.arrival_rate_per_s,
            mix_desc.join(","),
            svc.coordinator().machine().cfg.name,
            cfg.seed,
            mutate_desc,
            fleet_desc,
            batch_desc
        ),
    }
    let rep = svc.serve(&cfg)?;
    println!("{}", rep.summary());
    if let Some(tspec) = &cfg.trace {
        println!(
            "trace written: {} (+ {}) — open the first in Perfetto / chrome://tracing",
            tspec.path.display(),
            telemetry::telemetry_path(&tspec.path).display()
        );
    }
    if let Some(path) = args.opt("report-json") {
        let path = std::path::Path::new(path);
        rep.to_json().write_file(path)?;
        println!("report written: {}", path.display());
    }
    Ok(())
}

fn experiment_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.opt("config") {
        Some(p) => ExperimentConfig::from_file(std::path::Path::new(p))?,
        None => ExperimentConfig::default(),
    };
    if let Some(scale) = args.opt_parse::<u32>("scale")? {
        cfg.workload.graph.scale = scale;
    }
    if let Some(seed) = args.opt_parse::<u64>("seed")? {
        cfg.workload.graph.seed = seed;
    }
    if let Some(counts) = args.opt_list::<usize>("counts")? {
        cfg.workload.query_counts = counts;
    }
    if let Some(dir) = args.opt("results") {
        cfg.results_dir = dir.into();
    }
    if let Some(dir) = args.opt("artifacts") {
        cfg.artifacts_dir = dir.into();
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let cfg = experiment_config(args)?;
    eprintln!(
        "building experiment graph (scale {}, edge-factor {})...",
        cfg.workload.graph.scale, cfg.workload.graph.edge_factor
    );
    let h = Harness::new(cfg)?;
    eprintln!(
        "graph: {} vertices, {} directed edges",
        h.g.n(),
        h.g.m_directed()
    );

    let engine = if args.has_flag("measure-baseline") {
        let dir = if h.cfg.artifacts_dir.is_absolute() {
            h.cfg.artifacts_dir.clone()
        } else {
            default_artifacts_dir()
        };
        Some(Engine::from_dir(&dir)?)
    } else {
        None
    };

    match which {
        "fig3" => {
            fig3::report(&h)?;
        }
        "fig4" => {
            fig4::report(&h)?;
        }
        "table1" => {
            table1::report(&h)?;
        }
        "table2" => {
            table2::report(&h)?;
        }
        "table3" => {
            table3::report(&h, engine.as_ref())?;
        }
        "scaling" => {
            scaling::report(&h, args.opt_parse_or("queries", 128)?)?;
        }
        "ablation" => {
            ablation::report(&h)?;
        }
        "all" => {
            fig4::report(&h)?; // prints fig3's data as improvements
            fig3::report(&h)?;
            table1::report(&h)?;
            table2::report(&h)?;
            table3::report(&h, engine.as_ref())?;
            scaling::report(&h, args.opt_parse_or("queries", 128)?)?;
            ablation::report(&h)?;
            calibrate::report(&h)?;
        }
        other => bail!("unknown experiment {other:?}"),
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let cfg = experiment_config(args)?;
    let h = Harness::new(cfg)?;
    calibrate::report(&h)?;
    Ok(())
}

/// Dump the (possibly overridden) experiment config as editable JSON.
fn cmd_config(args: &Args) -> Result<()> {
    let out = args.opt("out").context("config needs --out FILE")?;
    let cfg = experiment_config(args)?;
    cfg.to_file(std::path::Path::new(out))?;
    println!("wrote {out} (machines: {})", cfg.machines.len());
    Ok(())
}

/// Run the PJRT GraphBLAS baseline engine end-to-end and report measured
/// times (the real execution behind Table III's model anchor).
fn cmd_baseline(args: &Args) -> Result<()> {
    let dir = args
        .opt("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let eng = Engine::from_dir(&dir)?;
    println!("PJRT platform: {}", eng.platform());
    let times = eng.compile_all()?;
    for (name, s) in &times {
        println!("  compiled {name} in {s:.3}s");
    }

    let n_art = eng.manifest().n;
    let scale = (n_art as f64).log2() as u32;
    let mut gcfg = graph_config(args)?;
    if gcfg.scale > scale {
        gcfg.scale = scale;
        eprintln!("(clamping graph to artifact dimension: scale {scale})");
    }
    let rmat = Rmat::new(gcfg.clone());
    let g = build_undirected_csr(gcfg.n_vertices() as usize, &rmat.edges());
    let gb = pathfinder_queries::baseline::GraphBlasEngine::new(&eng, &g)?;

    let k: usize = args.opt_parse_or("sources", 32)?;
    let srcs = pathfinder_queries::graph::sample::bfs_sources(&g, k, 11);
    let t0 = std::time::Instant::now();
    let res = gb.bfs(&srcs)?;
    let wall = t0.elapsed().as_secs_f64();
    for (i, &src) in srcs.iter().enumerate() {
        pathfinder_queries::alg::oracle::check_bfs(&g, src, &res.levels[i])?;
    }
    println!(
        "bfs x{k}: {} steps, {:.4}s exec ({:.4}s wall), results oracle-checked",
        res.steps, res.exec_s, wall
    );
    let cc = gb.cc()?;
    pathfinder_queries::alg::oracle::check_cc(&g, &cc.labels)?;
    println!("cc: {} iterations, {:.4}s exec, oracle-checked", cc.iterations, cc.exec_s);
    Ok(())
}
