//! Bench: the flow engine's allocator — the L3 hot path at paper-scale
//! concurrency (the §Perf optimization target). Synthetic phases isolate
//! the engine from graph traversal costs.
//!
//! Knobs: PFQ_BENCH_NQ (default 256) concurrent queries.

use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::sim::demand::PhaseDemand;
use pathfinder_queries::sim::flow::{Admission, FlowSim, OnFull, Priority, QuerySpec};
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::util::bench::{black_box, Bench};
use pathfinder_queries::util::rng::SplitMix64;

/// Synthetic multi-phase query resembling a BFS demand profile.
fn synth_query(rng: &mut SplitMix64, m: &Machine, id: usize) -> QuerySpec {
    let nodes = m.nodes();
    let cpn = m.cfg.channels_per_node;
    let phases = (0..8)
        .map(|_| {
            let mut p = PhaseDemand::zero(nodes, cpn);
            for node in 0..nodes {
                for c in 0..cpn {
                    let ops = rng.next_f64() * 2e4;
                    p.per_channel_ops[node * cpn + c] = ops;
                    p.channel_ops[node] += ops;
                    p.max_channel_ops[node] = p.max_channel_ops[node].max(ops);
                }
                p.instructions[node] = rng.next_f64() * 3e6;
                p.stream_bytes[node] = rng.next_f64() * 1e5;
            }
            p.parallelism = 1e4;
            p
        })
        .collect();
    QuerySpec::new(id, "synth", phases, 0.0)
        // Mixed priorities + a real context footprint so the admitted
        // bench exercises the ordered wait queue and byte accounting.
        .with_priority(Priority::ALL[id % 3])
        .with_ctx_bytes(16 << 20)
}

fn main() {
    let nq: usize = std::env::var("PFQ_BENCH_NQ")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let mut bench = Bench::from_env();

    for preset in ["pathfinder-8", "pathfinder-32"] {
        let m = Machine::new(MachineConfig::preset(preset).unwrap());
        let sim = FlowSim::new(m.clone());
        let mut rng = SplitMix64::new(7);
        let specs: Vec<QuerySpec> =
            (0..nq).map(|id| synth_query(&mut rng, &m, id)).collect();

        bench.run(&format!("{preset}/flow run x{nq} (8 phases each)"), || {
            black_box(sim.run(black_box(&specs)))
        });
        bench.run(&format!("{preset}/flow run x{}", nq / 4), || {
            black_box(sim.run(black_box(&specs[..nq / 4])))
        });
        bench.run(&format!("{preset}/sequential x{nq}"), || {
            black_box(sim.run_sequential(black_box(&specs)))
        });
        // Priority- and byte-aware admission at half the batch's footprint:
        // the ordered wait queue + shedding path under sustained overload.
        let adm = Admission::byte_budget(
            (nq as u64 / 2).max(1) * (16 << 20),
            OnFull::Shed { max_waiting: nq / 4 },
        );
        bench.run(&format!("{preset}/flow run_admitted(priority,bytes) x{nq}"), || {
            black_box(sim.run_admitted(black_box(&specs), black_box(adm)))
        });
        // solo_ns is called once per phase entry — the inner-loop cost.
        let p = &specs[0].phases[0];
        bench.run(&format!("{preset}/solo_ns (one phase)"), || {
            black_box(black_box(p).solo_ns(&m))
        });
        bench.run(&format!("{preset}/flow_resources (one phase)"), || {
            black_box(black_box(p).flow_resources(&m, 1e6))
        });
    }

    println!("== flow engine host wall times ==");
    for r in bench.results() {
        println!("{}", r.report());
    }
    // Events per second metric for the §Perf log.
    let per_run = bench.results()[0].median_s();
    let nq_f = nq as f64;
    println!(
        "\nallocator throughput: {:.0} phase-completions/s at {} concurrent queries",
        nq_f * 8.0 / per_run,
        nq
    );
}
