//! Bench: the flow engine's allocator — the L3 hot path at paper-scale
//! concurrency (the §Perf optimization target). Synthetic phases isolate
//! the engine from graph traversal costs.
//!
//! Knobs: PFQ_BENCH_NQ (default 256) concurrent queries.
//!
//! Doubles as the CI perf-regression gate (`bench-smoke` job): after the
//! wall-time benches it runs a deterministic mixed-priority gate scenario
//! whose *simulated* metrics have closed-form expected values under the
//! fluid model, writes them (plus wall medians) to `$PFQ_BENCH_JSON`, and
//! — when `$PFQ_BENCH_BASELINE` points at a checked-in baseline — exits
//! non-zero if any gated metric regressed by more than the baseline's
//! tolerance. Gating on simulated latency instead of wall time keeps the
//! gate deterministic on noisy CI runners: it catches engine/scheduling
//! regressions, while wall times stay informational.

use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::sim::cluster::Cluster;
use pathfinder_queries::sim::demand::PhaseDemand;
use pathfinder_queries::sim::flow::{
    Admission, FlowReport, FlowSim, OnFull, Priority, QuerySpec, ShareWeights, SolverMode,
};
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::util::bench::{black_box, Bench};
use pathfinder_queries::util::json::Json;
use pathfinder_queries::util::rng::SplitMix64;
use pathfinder_queries::util::stats::Quantiles;

/// Synthetic multi-phase query resembling a BFS demand profile.
fn synth_query(rng: &mut SplitMix64, m: &Machine, id: usize) -> QuerySpec {
    let nodes = m.nodes();
    let cpn = m.cfg.channels_per_node;
    let phases = (0..8)
        .map(|_| {
            let mut p = PhaseDemand::zero(nodes, cpn);
            for node in 0..nodes {
                for c in 0..cpn {
                    let ops = rng.next_f64() * 2e4;
                    p.per_channel_ops[node * cpn + c] = ops;
                    p.channel_ops[node] += ops;
                    p.max_channel_ops[node] = p.max_channel_ops[node].max(ops);
                }
                p.instructions[node] = rng.next_f64() * 3e6;
                p.stream_bytes[node] = rng.next_f64() * 1e5;
            }
            p.parallelism = 1e4;
            p
        })
        .collect();
    QuerySpec::new(id, "synth", phases, 0.0)
        // Mixed priorities + a real context footprint so the admitted
        // bench exercises the ordered wait queue and byte accounting.
        .with_priority(Priority::ALL[id % 3])
        .with_ctx_bytes(16 << 20)
}

/// The gate workload: 48 identical single-phase queries, 16 per priority
/// class, all arriving at t=0, each demanding 50% of every channel
/// uniformly ([`PhaseDemand::uniform_channel_load`]) — a saturating mixed
/// workload (aggregate demand 24x capacity) whose completion times are
/// closed-form under the fluid model.
fn gate_specs(m: &Machine) -> Vec<QuerySpec> {
    (0..48)
        .map(|id| {
            let phase = PhaseDemand::uniform_channel_load(m, 0.5, 1e6);
            QuerySpec::new(id, "gate", vec![phase], 0.0).with_priority(Priority::ALL[id % 3])
        })
        .collect()
}

/// The mixed query+mutation gate scenario (DESIGN.md §Mutation): the 48
/// gate queries plus 8 ingest batches of the same uniform shape, all
/// Batch-class (the mutation lane's admission class), all at t=0 —
/// mutation traffic competing for the same channels inside the same
/// engine. Aggregate demand 28x capacity; every completion time is
/// closed-form (the per-query channel drain is 0.5e6 ns and the solo time
/// cancels):
///
/// * flat: all 56 specs share equally and finish together at
///   `56 x 0.5e6 ns` — mean latency 0.028 s;
/// * weighted 4:2:1 (class weight sums 64/32/24): Interactive finishes at
///   15e6 ns (0.015 s); Standard at 22e6; the Batch pool — 16 queries + 8
///   mutation batches — at 28e6, so the mutate-lane mean is 0.028 s.
fn mutation_gate_specs(m: &Machine) -> Vec<QuerySpec> {
    let mut specs = gate_specs(m);
    for i in 0..8 {
        let phase = PhaseDemand::uniform_channel_load(m, 0.5, 1e6);
        specs.push(
            QuerySpec::new(48 + i, "mutate", vec![phase], 0.0).with_priority(Priority::Batch),
        );
    }
    specs
}

/// The mixed-analyses gate scenario (PR 5): 24 identical single-phase
/// uniform-load queries, labeled/classed as 8 Interactive `bfs`, 8
/// Standard `pagerank`, and 8 Batch `tricount` — the two new analytic
/// kernels riding the scheduler as first-class labels. With per-query
/// channel drain `D = 0.5e6 ns` (solo time cancels), completion times are
/// closed-form:
///
/// * flat: all 24 share equally and finish together at `24 x D = 12e6 ns`
///   — mean latency 0.012 s;
/// * weighted 4:2:1 (class weight sums 32/16/8, Σ n_c w_c = 56):
///   Interactive finishes at `56D/4 = 7e6 ns`; Standard (`pagerank`) then
///   drains its remaining `0.5D` at rate 2/24, finishing at `20D = 10e6
///   ns` (0.010 s); Batch (`tricount`) finishes last at `24D = 12e6 ns`
///   (0.012 s — the work-conserving flat makespan).
fn analysis_gate_specs(m: &Machine) -> Vec<QuerySpec> {
    const CLASSES: [(&str, Priority); 3] = [
        ("bfs", Priority::Interactive),
        ("pagerank", Priority::Standard),
        ("tricount", Priority::Batch),
    ];
    let mut specs = Vec::new();
    for (label, priority) in CLASSES {
        for _ in 0..8 {
            let id = specs.len();
            let phase = PhaseDemand::uniform_channel_load(m, 0.5, 1e6);
            specs.push(QuerySpec::new(id, label, vec![phase], 0.0).with_priority(priority));
        }
    }
    specs
}

/// The fleet gate scenario (DESIGN.md §Fleet): a 4-shard single-replica
/// fleet of pathfinder-8 chassis (32 nodes on one flattened machine) runs
/// 16 identical single-phase queries — 8 Interactive `bfs`, 8 Batch `cc`
/// — each shaped by [`PhaseDemand::uniform_fleet_load`]: 50% uniform
/// channel load worth 0.5e6 ns plus a 1e6 ns fleet-interconnect drain on
/// every node, so the interconnect is the binding resource and every
/// completion time is closed-form (solo time cancels):
///
/// * flat: 16 queries share each node's interconnect equally and finish
///   together at `16 x 1e6 ns` — mean latency 0.016 s (the channel lane
///   would finish at 8e6 ns, strictly earlier, so it never binds);
/// * weighted 4:2:1 (Σ n_c w_c = 8x4 + 8x1 = 40): `bfs` drains at rate
///   4/40 and finishes at `40e6/4 = 10e6 ns` (0.010 s); `cc` then takes
///   the freed bandwidth and finishes at the work-conserving makespan
///   `16e6 ns` (0.016 s).
fn fleet_gate_specs(m: &Machine) -> Vec<QuerySpec> {
    const CLASSES: [(&str, Priority); 2] =
        [("bfs", Priority::Interactive), ("cc", Priority::Batch)];
    let mut specs = Vec::new();
    for (label, priority) in CLASSES {
        for _ in 0..8 {
            let id = specs.len();
            let phase = PhaseDemand::uniform_fleet_load(m, 0.5, 1e6, 1e6);
            specs.push(QuerySpec::new(id, label, vec![phase], 0.0).with_priority(priority));
        }
    }
    specs
}

/// The batched-BFS gate scenario (DESIGN.md §Batching): `n` identical
/// same-epoch single-phase BFS-shaped queries, all at t=0, each demanding
/// 50% of every channel uniformly (drain `D = 0.5e6 ns`; the solo time
/// cancels). Unbatched, all 32 share every channel and finish together at
/// `32 x D = 16e6 ns` — mean latency 0.016 s. The coordinator batcher at
/// width 16 fuses them into **2** engine queries of the SAME single-phase
/// shape (the MS-BFS fusion win: one shared sweep per group, not 16),
/// which finish at `2 x D = 1e6 ns`; every member's latency is fused
/// finish − its own arrival = 0.001 s, a 16x mean-latency improvement
/// (ratio 0.0625 — gated in-bench to stay ≤ 0.5, the PR acceptance
/// bound, and pinned by `ci/BENCH_baseline.json`).
fn batched_gate_specs(m: &Machine, n: usize) -> Vec<QuerySpec> {
    (0..n)
        .map(|id| {
            let phase = PhaseDemand::uniform_channel_load(m, 0.5, 1e6);
            QuerySpec::new(id, "bfs", vec![phase], 0.0)
        })
        .collect()
}

/// Host wall-clock per *simulated event* at three concurrency levels —
/// the PR 7 tentpole axis. The workload weak-scales: 64 queries per
/// pathfinder-8 chassis of a flattened fleet ([`Cluster`]), each query
/// three chassis-local phases ([`PhaseDemand::uniform_channel_load_span`]
/// anchored at the query's chassis) with jittered solo times and mixed
/// priorities, all arriving at t=0 under unlimited admission. Every
/// event's connected component is one chassis (~64 queries), so the
/// incremental solver's per-event cost should stay near-flat as total
/// concurrency grows from 10³ to 10⁵; the dense mode re-solves every
/// component on every event and is measured at 1k only, as a contrast.
struct HostScaling {
    /// (concurrency level, simulated events, median host ns per event).
    levels: Vec<(usize, usize, f64)>,
    /// Dense-mode ns/event at the smallest level (informational).
    dense_1k: f64,
}

impl HostScaling {
    fn ns_at(&self, level: usize) -> f64 {
        self.levels.iter().find(|&&(l, _, _)| l == level).map(|&(_, _, ns)| ns).unwrap()
    }

    /// The gated, machine-speed-independent figure: how much more host
    /// time an event costs at 100k concurrency than at 1k.
    fn ratio_100k_over_1k(&self) -> f64 {
        self.ns_at(100_000) / self.ns_at(1_000)
    }
}

/// Build the weak-scaled fleet workload for one concurrency level.
fn host_scaling_workload(level: usize) -> (Machine, Vec<QuerySpec>) {
    let base = MachineConfig::preset("pathfinder-8").unwrap();
    let chassis = level.div_ceil(64);
    let m = Cluster::new(&base, chassis, 1).machine().clone();
    let npc = base.nodes;
    let mut rng = SplitMix64::new(0xBEEF ^ level as u64);
    let specs = (0..level)
        .map(|id| {
            let node_offset = (id / 64) * npc;
            let phases = (0..3)
                .map(|_| {
                    // Jitter solo times so completions interleave instead
                    // of retiring in lockstep waves.
                    let total_ns = 0.5e6 * (0.75 + 0.5 * rng.next_f64());
                    PhaseDemand::uniform_channel_load_span(&m, 0.5, total_ns, node_offset, npc)
                })
                .collect();
            QuerySpec::new(id, "scale", phases, 0.0).with_priority(Priority::ALL[id % 3])
        })
        .collect();
    (m, specs)
}

/// Median host ns per simulated event over `runs` runs of the workload.
fn host_ns_per_event(m: &Machine, specs: &[QuerySpec], mode: SolverMode, runs: usize) -> f64 {
    let sim = FlowSim::new(m.clone()).with_solver_mode(mode);
    let mut samples: Vec<f64> = (0..runs)
        .map(|_| {
            let t = std::time::Instant::now();
            let rep = black_box(sim.run_admitted(black_box(specs), Admission::unlimited()));
            let dt = t.elapsed().as_secs_f64();
            assert!(rep.events > 0, "host-scaling run produced no events");
            assert!(
                rep.timings.iter().all(|q| q.completed()),
                "host-scaling: every query must complete"
            );
            dt * 1e9 / rep.events as f64
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Measure the host-cost scaling axis and print the table.
fn host_scaling() -> HostScaling {
    println!("\n== host cost per simulated event (weak-scaled fleet, 64 queries/chassis) ==");
    println!(
        "{:>10} {:>9} {:>10} {:>12}  solver",
        "queries", "chassis", "events", "ns/event"
    );
    let mut levels = Vec::new();
    let mut dense_1k = 0.0;
    // 100k is a single run (it dominates wall time); the cheaper levels
    // take a median of 3 to damp host noise.
    for (level, runs) in [(1_000usize, 3usize), (10_000, 3), (100_000, 1)] {
        let (m, specs) = host_scaling_workload(level);
        let ns = host_ns_per_event(&m, &specs, SolverMode::Incremental, runs);
        // Events are deterministic across runs; recompute once for the
        // table (starts + phase retirements: 4 per 3-phase query).
        let events = 4 * level;
        println!(
            "{:>10} {:>9} {:>10} {:>12.0}  incremental",
            level,
            level.div_ceil(64),
            events,
            ns
        );
        levels.push((level, events, ns));
        if level == 1_000 {
            dense_1k = host_ns_per_event(&m, &specs, SolverMode::Dense, 1);
            println!(
                "{:>10} {:>9} {:>10} {:>12.0}  dense (reference)",
                level,
                level.div_ceil(64),
                events,
                dense_1k
            );
        }
    }
    let hs = HostScaling { levels, dense_1k };
    println!(
        "host cost ratio 100k/1k = {:.2}x (incremental); dense/incremental at 1k = {:.1}x",
        hs.ratio_100k_over_1k(),
        hs.dense_1k / hs.ns_at(1_000)
    );
    hs
}

/// Deterministic gate metrics with fluid-model closed forms (per-channel
/// drain is `0.5e6 ns` per query, and the solo time cancels out of every
/// completion time):
///
/// * unweighted: all 48 queries share equally and finish together at
///   `48 x 0.5e6 ns` — mean latency 0.024 s;
/// * weighted 4:2:1: Interactive finishes at `(16x7) x 0.5e6 / 4 = 14e6
///   ns` (0.014 s), Standard at 20e6, Batch at 24e6 — mean 0.019333 s.
///
/// `ci/BENCH_baseline.json` checks in exactly these values.
fn gate_metrics() -> (Vec<(&'static str, f64)>, Json) {
    let m = Machine::new(MachineConfig::preset("pathfinder-8").unwrap());
    let sim = FlowSim::new(m.clone());
    let specs = gate_specs(&m);
    let flat = sim.run_admitted(&specs, Admission::unlimited());
    let weighted = sim.run_admitted(
        &specs,
        Admission::unlimited().with_weights(ShareWeights::priority_weighted()),
    );
    // Mixed query+mutation scenario (see [`mutation_gate_specs`]).
    let mspecs = mutation_gate_specs(&m);
    let mflat = sim.run_admitted(&mspecs, Admission::unlimited());
    let mweighted = sim.run_admitted(
        &mspecs,
        Admission::unlimited().with_weights(ShareWeights::priority_weighted()),
    );
    // Mixed-analyses scenario (see [`analysis_gate_specs`]).
    let aspecs = analysis_gate_specs(&m);
    let aflat = sim.run_admitted(&aspecs, Admission::unlimited());
    let aweighted = sim.run_admitted(
        &aspecs,
        Admission::unlimited().with_weights(ShareWeights::priority_weighted()),
    );
    // Fleet scenario (see [`fleet_gate_specs`]): its own 4x1 fleet of
    // pathfinder-8 chassis, flattened to one 32-node machine.
    let fm = Cluster::new(&m.cfg, 4, 1).machine().clone();
    let fsim = FlowSim::new(fm.clone());
    let fspecs = fleet_gate_specs(&fm);
    let fflat = fsim.run_admitted(&fspecs, Admission::unlimited());
    let fweighted = fsim.run_admitted(
        &fspecs,
        Admission::unlimited().with_weights(ShareWeights::priority_weighted()),
    );
    // Batched-BFS scenario (see [`batched_gate_specs`]): 32 same-epoch
    // BFS unbatched vs the width-16 batcher's 2 fused sweeps. Both fused
    // groups are width 16, so the engine's mean over the 2 fused timings
    // IS the per-member mean (each member's latency = its group's finish
    // − its own arrival, and every arrival is 0).
    let bspecs = batched_gate_specs(&m, 32);
    let bunbatched = sim.run_admitted(&bspecs, Admission::unlimited());
    let bfused_specs = batched_gate_specs(&m, 2);
    let bfused = sim.run_admitted(&bfused_specs, Admission::unlimited());
    // Guard the gate's own validity: the closed forms assume every spec
    // completes, and the mean-latency accessors are None otherwise —
    // fail loudly with scenario names instead of a bare unwrap.
    for (name, rep, len) in [
        ("mixed_mutation/flat", &mflat, mspecs.len()),
        ("mixed_mutation/weighted", &mweighted, mspecs.len()),
        ("analyses/flat", &aflat, aspecs.len()),
        ("analyses/weighted", &aweighted, aspecs.len()),
        ("fleet/flat", &fflat, fspecs.len()),
        ("fleet/weighted", &fweighted, fspecs.len()),
        ("batched/unbatched", &bunbatched, bspecs.len()),
        ("batched/fused", &bfused, bfused_specs.len()),
    ] {
        let done = rep.timings.iter().filter(|t| t.completed()).count();
        assert_eq!(done, len, "{name}: every gate spec must complete");
    }
    // The PR acceptance bound, enforced in-bench so the gate fails even
    // without a baseline file: fusing 32 same-epoch BFS at width 16 must
    // at least halve the mean latency (the closed forms give 16x).
    let bfused_mean = bfused.mean_latency_s().expect("batched/fused completed");
    let bunbatched_mean = bunbatched.mean_latency_s().expect("batched/unbatched completed");
    let batched_ratio = bfused_mean / bunbatched_mean;
    assert!(
        batched_ratio <= 0.5,
        "batched gate: fused mean latency {bfused_mean} s must be <= 0.5x the \
         unbatched {bunbatched_mean} s (ratio {batched_ratio})"
    );
    assert_eq!(
        mflat.label_latencies_s("mutate").len(),
        8,
        "mixed_mutation: the mutate lane must complete"
    );
    for label in ["pagerank", "tricount"] {
        assert_eq!(
            aweighted.label_latencies_s(label).len(),
            8,
            "analyses: the {label} class must complete"
        );
    }
    for label in ["bfs", "cc"] {
        assert_eq!(
            fweighted.label_latencies_s(label).len(),
            8,
            "fleet: the {label} class must complete"
        );
    }
    let metrics = vec![
        ("mixed/unweighted/mean_latency_s", flat.mean_latency_s().expect("mixed/flat")),
        ("mixed/weighted/mean_latency_s", weighted.mean_latency_s().expect("mixed/weighted")),
        (
            "mixed/weighted/interactive_mean_latency_s",
            weighted.class_mean_latency_s(Priority::Interactive).expect("mixed/weighted"),
        ),
        (
            "mixed_mutation/unweighted/mean_latency_s",
            mflat.mean_latency_s().expect("mixed_mutation/flat"),
        ),
        (
            "mixed_mutation/weighted/interactive_mean_latency_s",
            mweighted
                .class_mean_latency_s(Priority::Interactive)
                .expect("mixed_mutation/weighted"),
        ),
        (
            "mixed_mutation/weighted/mutate_mean_latency_s",
            mweighted.label_mean_latency_s("mutate").expect("mixed_mutation/mutate lane"),
        ),
        ("analyses/unweighted/mean_latency_s", aflat.mean_latency_s().expect("analyses/flat")),
        (
            "analyses/weighted/pagerank_mean_latency_s",
            aweighted.label_mean_latency_s("pagerank").expect("analyses/pagerank"),
        ),
        (
            "analyses/weighted/tricount_mean_latency_s",
            aweighted.label_mean_latency_s("tricount").expect("analyses/tricount"),
        ),
        ("fleet/unweighted/mean_latency_s", fflat.mean_latency_s().expect("fleet/flat")),
        (
            "fleet/weighted/bfs_mean_latency_s",
            fweighted.label_mean_latency_s("bfs").expect("fleet/bfs"),
        ),
        (
            "fleet/weighted/cc_mean_latency_s",
            fweighted.label_mean_latency_s("cc").expect("fleet/cc"),
        ),
        ("batched/unbatched/mean_latency_s", bunbatched_mean),
        ("batched/fused/mean_latency_s", bfused_mean),
        ("batched/latency_ratio", batched_ratio),
    ];
    // The standardized per-scenario class matrix (p50/p95/p99 per priority
    // class) that rides along in BENCH_pr.json — informational, not gated.
    let class_matrix = Json::obj(vec![
        ("mixed/unweighted", class_matrix_row(&flat)),
        ("mixed/weighted", class_matrix_row(&weighted)),
        ("mixed_mutation/unweighted", class_matrix_row(&mflat)),
        ("mixed_mutation/weighted", class_matrix_row(&mweighted)),
        ("analyses/unweighted", class_matrix_row(&aflat)),
        ("analyses/weighted", class_matrix_row(&aweighted)),
        ("fleet/unweighted", class_matrix_row(&fflat)),
        ("fleet/weighted", class_matrix_row(&fweighted)),
        ("batched/unbatched", class_matrix_row(&bunbatched)),
        ("batched/fused", class_matrix_row(&bfused)),
    ]);
    (metrics, class_matrix)
}

/// One class-matrix row: per priority class, completed count + p50/p95/p99
/// latency (seconds); `null` for a class with no completions.
fn class_matrix_row(rep: &FlowReport) -> Json {
    let cell = |p: Priority, name: &str| {
        let xs = rep.class_latencies_s(p);
        let v = match Quantiles::try_from_samples(&xs) {
            None => Json::Null,
            Some(q) => Json::obj(vec![
                ("n", Json::Num(xs.len() as f64)),
                ("p50_s", Json::Num(q.q50)),
                ("p95_s", Json::Num(q.q95)),
                ("p99_s", Json::Num(q.q99)),
            ]),
        };
        (name, v)
    };
    Json::obj(vec![
        cell(Priority::Interactive, "interactive"),
        cell(Priority::Standard, "standard"),
        cell(Priority::Batch, "batch"),
    ])
}

/// The run-environment record written into BENCH_pr.json so any archived
/// report is attributable: commit, toolchain, host triple, presets, seed.
fn environment() -> Json {
    let env_or = |keys: &[&str]| {
        keys.iter().find_map(|k| std::env::var(k).ok()).map_or(Json::Null, Json::str)
    };
    Json::obj(vec![
        ("git_commit", env_or(&["PFQ_GIT_COMMIT", "GITHUB_SHA"])),
        ("toolchain", env_or(&["RUSTUP_TOOLCHAIN"])),
        ("os", Json::str(std::env::consts::OS)),
        ("arch", Json::str(std::env::consts::ARCH)),
        ("gate_machine", Json::str("pathfinder-8")),
        ("synth_seed", Json::Num(7.0)),
        ("host_scaling_seed", Json::str("0xBEEF ^ level")),
    ])
}

/// Emit `$PFQ_BENCH_JSON` and enforce `$PFQ_BENCH_BASELINE`; returns
/// false when a gated metric regressed beyond the baseline tolerance.
fn run_gate(bench: &Bench, host: &HostScaling) -> bool {
    let (metrics, class_matrix) = gate_metrics();
    println!("\n== bench-gate metrics (simulated, deterministic) ==");
    for (k, v) in &metrics {
        println!("  {k} = {v:.9}");
    }
    if let Ok(path) = std::env::var("PFQ_BENCH_JSON") {
        let obj = Json::obj(vec![
            ("schema", Json::num(2.0)),
            ("environment", environment()),
            ("class_matrix", class_matrix),
            (
                "metrics",
                Json::Obj(
                    metrics.iter().map(|&(k, v)| (k.to_string(), Json::Num(v))).collect(),
                ),
            ),
            (
                "host_scaling",
                Json::obj(vec![
                    ("host_ns_per_event_1k", Json::Num(host.ns_at(1_000))),
                    ("host_ns_per_event_10k", Json::Num(host.ns_at(10_000))),
                    ("host_ns_per_event_100k", Json::Num(host.ns_at(100_000))),
                    ("ratio_100k_over_1k", Json::Num(host.ratio_100k_over_1k())),
                    ("dense_host_ns_per_event_1k", Json::Num(host.dense_1k)),
                ]),
            ),
            (
                "wall_median_s",
                Json::Obj(
                    bench
                        .results()
                        .iter()
                        .map(|r| (r.name.clone(), Json::Num(r.median_s())))
                        .collect(),
                ),
            ),
        ]);
        obj.write_file(std::path::Path::new(&path)).expect("writing bench json");
        println!("bench-gate: wrote {path}");
    }
    let Ok(base_path) = std::env::var("PFQ_BENCH_BASELINE") else {
        return true;
    };
    let base = Json::parse_file(std::path::Path::new(&base_path)).expect("reading baseline");
    let tol = base
        .get_opt("tolerance_pct")
        .and_then(|j| j.as_f64().ok())
        .unwrap_or(20.0);
    // Fast-path regression guard: metrics listed in `strict_metrics` must
    // be UNCHANGED (to `strict_tolerance_pct`, both directions) — these
    // are the no-mutation scenario's closed forms, pinned so the mutation
    // subsystem's zero-overhead fast path cannot drift (DESIGN.md
    // §Mutation).
    let strict_tol = base
        .get_opt("strict_tolerance_pct")
        .and_then(|j| j.as_f64().ok())
        .unwrap_or(0.01);
    let strict: Vec<String> = base
        .get_opt("strict_metrics")
        .and_then(|j| j.as_arr().ok().map(|xs| xs.to_vec()))
        .unwrap_or_default()
        .iter()
        .filter_map(|j| j.as_str().ok().map(String::from))
        .collect();
    let expect = match base.get("metrics") {
        Ok(Json::Obj(map)) => map.clone(),
        _ => panic!("baseline {base_path} has no metrics object"),
    };
    let mut ok = true;
    for (k, v) in &expect {
        let want = v.as_f64().expect("numeric baseline metric");
        match metrics.iter().find(|(name, _)| name == k) {
            None => {
                eprintln!("bench-gate: baseline metric {k:?} missing from this run");
                ok = false;
            }
            Some(&(_, got)) => {
                let delta_pct = (got - want) / want * 100.0;
                if strict.iter().any(|s| s == k) {
                    if delta_pct.abs() > strict_tol {
                        eprintln!(
                            "bench-gate: STRICT metric {k} moved {delta_pct:+.4}% \
                             ({want:.9} -> {got:.9}) — the no-mutation fast path \
                             must stay bit-stable (tolerance {strict_tol}%)"
                        );
                        ok = false;
                    }
                } else if delta_pct > tol {
                    eprintln!(
                        "bench-gate: {k} regressed {delta_pct:.1}% \
                         ({want:.6} -> {got:.6}), tolerance {tol}%"
                    );
                    ok = false;
                } else if delta_pct < -tol {
                    println!(
                        "bench-gate: {k} improved {:.1}% — consider refreshing {base_path}",
                        -delta_pct
                    );
                }
            }
        }
    }
    // Host-cost scaling gate (the incremental-solver criterion): the
    // DIMENSIONLESS 100k/1k ns-per-event ratio must stay under the
    // baseline bound plus tolerance. Gating on the ratio rather than
    // absolute ns keeps the gate machine-speed independent; the absolute
    // numbers in BENCH_pr.json are informational.
    if let Some(hs) = base.get_opt("host_scaling") {
        let max = hs
            .f64_of("ratio_100k_over_1k_max")
            .expect("host_scaling.ratio_100k_over_1k_max");
        let htol = hs
            .get_opt("tolerance_pct")
            .and_then(|j| j.as_f64().ok())
            .unwrap_or(30.0);
        let bound = max * (1.0 + htol / 100.0);
        let got = host.ratio_100k_over_1k();
        if got > bound {
            eprintln!(
                "bench-gate: host ns/event ratio 100k/1k = {got:.2} exceeds \
                 baseline {max} (+{htol}% tolerance = {bound:.2}) — the \
                 event-scoped solver's per-event cost must stay near-flat \
                 in concurrency"
            );
            ok = false;
        } else {
            println!(
                "bench-gate: host ns/event ratio 100k/1k = {got:.2} \
                 (bound {bound:.2})"
            );
        }
    }
    if ok {
        println!(
            "bench-gate: all metrics within {tol}% of {base_path} \
             ({} strict fast-path metrics within {strict_tol}%)",
            strict.len()
        );
    }
    ok
}

fn main() {
    let nq: usize = std::env::var("PFQ_BENCH_NQ")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let mut bench = Bench::from_env();

    for preset in ["pathfinder-8", "pathfinder-32"] {
        let m = Machine::new(MachineConfig::preset(preset).unwrap());
        let sim = FlowSim::new(m.clone());
        let mut rng = SplitMix64::new(7);
        let specs: Vec<QuerySpec> =
            (0..nq).map(|id| synth_query(&mut rng, &m, id)).collect();

        bench.run(&format!("{preset}/flow run x{nq} (8 phases each)"), || {
            black_box(sim.run(black_box(&specs)))
        });
        bench.run(&format!("{preset}/flow run x{}", nq / 4), || {
            black_box(sim.run(black_box(&specs[..nq / 4])))
        });
        bench.run(&format!("{preset}/sequential x{nq}"), || {
            black_box(sim.run_sequential(black_box(&specs)))
        });
        // Priority- and byte-aware admission at half the batch's footprint:
        // the ordered wait queue + shedding path under sustained overload.
        let adm = Admission::byte_budget(
            (nq as u64 / 2).max(1) * (16 << 20),
            OnFull::Shed { max_waiting: nq / 4 },
        );
        bench.run(&format!("{preset}/flow run_admitted(priority,bytes) x{nq}"), || {
            black_box(sim.run_admitted(black_box(&specs), black_box(adm)))
        });
        // Weighted fair share + checkpoint preemption: the cap/weight
        // branches of the allocator and the park/resume path.
        let wadm = adm
            .with_weights(ShareWeights::priority_weighted())
            .with_preempt(pathfinder_queries::sim::preempt::PreemptPolicy::default());
        bench.run(&format!("{preset}/flow run_admitted(weights,preempt) x{nq}"), || {
            black_box(sim.run_admitted(black_box(&specs), black_box(wadm)))
        });
        // solo_ns is called once per phase entry — the inner-loop cost.
        let p = &specs[0].phases[0];
        bench.run(&format!("{preset}/solo_ns (one phase)"), || {
            black_box(black_box(p).solo_ns(&m))
        });
        bench.run(&format!("{preset}/flow_resources (one phase)"), || {
            black_box(black_box(p).flow_resources(&m, 1e6))
        });
    }

    println!("== flow engine host wall times ==");
    for r in bench.results() {
        println!("{}", r.report());
    }
    // Events per second metric for the §Perf log.
    let per_run = bench.results()[0].median_s();
    let nq_f = nq as f64;
    println!(
        "\nallocator throughput: {:.0} phase-completions/s at {} concurrent queries",
        nq_f * 8.0 / per_run,
        nq
    );

    // Host-cost-per-event scaling axis (see [`host_scaling`]): always
    // measured — the 100k level is a single run and the gate needs it.
    let host = host_scaling();

    // CI perf-regression gate: the deterministic metrics always print;
    // writing BENCH_pr.json and enforcing the baseline happen only when
    // $PFQ_BENCH_JSON / $PFQ_BENCH_BASELINE are set (see module doc).
    if !run_gate(&bench, &host) {
        std::process::exit(1);
    }
}
