//! Bench: the Figure-3/4 experiment end to end.
//!
//! Two things are measured here, deliberately kept apart:
//!
//! * the **simulated** result (the paper's table — concurrent vs
//!   sequential makespans and the improvement %), and
//! * the **host wall time** of regenerating it (the §Perf L3 numbers:
//!   demand preparation and the flow engine's allocator are the hot
//!   paths of this repo).
//!
//! Knobs: PFQ_BENCH_SCALE (default 14), PFQ_BENCH_QUERIES (default 64),
//! BENCH_SAMPLES / BENCH_WARMUP for the runner.

use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::coordinator::{planner, Coordinator, ImprovementRow, Policy};
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::rmat::Rmat;
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::util::bench::{black_box, Bench};

fn env(name: &str, default: u32) -> u32 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let scale = env("PFQ_BENCH_SCALE", 14);
    let k = env("PFQ_BENCH_QUERIES", 64) as usize;
    let gcfg = GraphConfig::with_scale(scale);
    let g = build_undirected_csr(gcfg.n_vertices() as usize, &Rmat::new(gcfg).edges());
    println!(
        "fig3 bench: scale {scale} ({} vertices, {} directed edges), {k} BFS queries\n",
        g.n(),
        g.m_directed()
    );

    let mut bench = Bench::from_env();
    for preset in ["pathfinder-8", "pathfinder-32"] {
        let coord = Coordinator::new(&g, Machine::new(MachineConfig::preset(preset).unwrap()));
        let queries = planner::bfs_queries(&g, k, 0xBF5);

        // Host cost of demand preparation (functional BFS + demand vectors).
        bench.run(&format!("{preset}/prepare x{k}"), || {
            black_box(coord.prepare(black_box(&queries)))
        });

        let specs = coord.prepare(&queries);
        // Host cost of the concurrent flow solve.
        bench.run(&format!("{preset}/flow concurrent x{k}"), || {
            black_box(coord.run_specs(&queries, &specs, Policy::Concurrent).unwrap())
        });
        bench.run(&format!("{preset}/flow sequential x{k}"), || {
            black_box(coord.run_specs(&queries, &specs, Policy::Sequential).unwrap())
        });

        // The simulated result itself (the paper table row).
        let conc = coord.run_specs(&queries, &specs, Policy::Concurrent).unwrap();
        let seq = coord.run_specs(&queries, &specs, Policy::Sequential).unwrap();
        let row = ImprovementRow::from_reports(&conc, &seq);
        println!(
            "  simulated: conc {:.4}s  seq {:.4}s  improvement {:.1}%\n",
            row.concurrent_s,
            row.sequential_s,
            row.improvement_pct()
        );
    }

    println!("\n== host wall times ==");
    for r in bench.results() {
        println!("{}", r.report());
    }
}
