//! Bench: host-side functional traversal throughput — `bfs_run` /
//! `cc_run` edges per second. This is the dominant cost of preparing
//! paper-scale experiments (750 queries x millions of edges), so it is the
//! first §Perf L3 target: the DESIGN.md goal is >= 100 M edges/s.
//!
//! Knobs: PFQ_BENCH_SCALE (default 15).

use pathfinder_queries::alg;
use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::rmat::Rmat;
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::util::bench::{black_box, Bench};

fn main() {
    let scale: u32 = std::env::var("PFQ_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(15);
    let gcfg = GraphConfig::with_scale(scale);
    let g = build_undirected_csr(gcfg.n_vertices() as usize, &Rmat::new(gcfg).edges());
    let m = Machine::new(MachineConfig::pathfinder_8());
    let src = pathfinder_queries::graph::sample::bfs_sources(&g, 1, 1)[0];
    println!(
        "bfs_host bench: scale {scale} ({} vertices, {} directed edges)\n",
        g.n(),
        g.m_directed()
    );

    let mut bench = Bench::from_env();
    bench.run("oracle/bfs (plain queue)", || black_box(alg::oracle::bfs_levels(&g, src)));
    bench.run("oracle/cc (union-find)", || black_box(alg::oracle::cc_labels(&g)));
    bench.run("alg/bfs_run (functional + demand)", || {
        black_box(alg::bfs_run(&g, &m, src))
    });
    bench.run("alg/cc_run (functional + demand)", || black_box(alg::cc_run(&g, &m)));

    println!("== host wall times ==");
    for r in bench.results() {
        println!("{}", r.report());
    }

    let m_edges = g.m_directed() as f64;
    let bfs_t = bench.results()[2].median_s();
    let oracle_t = bench.results()[0].median_s();
    println!(
        "\nbfs_run throughput: {:.1} M edges/s (oracle: {:.1} M edges/s, \
         demand overhead {:.2}x)",
        m_edges / bfs_t / 1e6,
        m_edges / oracle_t / 1e6,
        bfs_t / oracle_t
    );
}
