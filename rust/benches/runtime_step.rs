//! Bench: PJRT step-execution latency per AOT variant — compile time once,
//! then per-call execute cost of `bfs_step` (by batch) and `cc_step`. The
//! L1/L2 §Perf evidence: batching amortizes the per-call overhead, and the
//! per-step cost is what the Xeon model's anchor measures.
//!
//! Skips cleanly when artifacts are absent (`make artifacts`).

use pathfinder_queries::runtime::artifact::default_artifacts_dir;
use pathfinder_queries::runtime::Engine;
use pathfinder_queries::util::bench::{black_box, Bench};

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("runtime_step bench: artifacts missing — run `make artifacts`; skipping");
        return;
    }
    let eng = match Engine::from_dir(&dir) {
        Ok(eng) => eng,
        Err(e) => {
            // Built without the `pjrt` feature: the stub engine refuses.
            println!("runtime_step bench: {e}; skipping");
            return;
        }
    };
    println!("runtime_step bench: platform {}", eng.platform());

    // Compile cost per variant (once; cached afterwards).
    for (name, s) in eng.compile_all().unwrap() {
        println!("  compile {name:<24} {:.3}s", s);
    }
    let n = eng.manifest().n;

    // A ring graph in the padded adjacency keeps every step busy.
    let mut adj = vec![0.0f32; n * n];
    for v in 0..n {
        adj[v * n + (v + 1) % n] = 1.0;
        adj[((v + 1) % n) * n + v] = 1.0;
    }

    let mut bench = Bench::from_env();
    let entries: Vec<_> = eng.manifest().by_kind("bfs_step").into_iter().cloned().collect();
    for e in &entries {
        let b = e.batch;
        let mut frontier = vec![0.0f32; b * n];
        let mut visited = vec![0.0f32; b * n];
        let levels = vec![-1.0f32; b * n];
        for q in 0..b {
            frontier[q * n + q % n] = 1.0;
            visited[q * n + q % n] = 1.0;
        }
        bench.run(&format!("bfs_step b={b}"), || {
            black_box(
                eng.execute_f32(
                    &e.name,
                    &[
                        (&adj, &[n as i64, n as i64]),
                        (&frontier, &[b as i64, n as i64]),
                        (&visited, &[b as i64, n as i64]),
                        (&levels, &[b as i64, n as i64]),
                        (&[1.0f32], &[]),
                    ],
                )
                .unwrap(),
            )
        });
    }
    if let Some(e) = eng.manifest().cc_variant().cloned() {
        let labels: Vec<f32> = (0..n).map(|i| i as f32).collect();
        bench.run("cc_step", || {
            black_box(
                eng.execute_f32(
                    &e.name,
                    &[(&adj, &[n as i64, n as i64]), (&labels, &[n as i64])],
                )
                .unwrap(),
            )
        });
    }

    println!("\n== per-step execute cost (n={n}) ==");
    for r in bench.results() {
        println!("{}", r.report());
    }
    if entries.len() >= 2 {
        let first = bench.results()[0].median_s();
        let last = bench.results()[entries.len() - 1].median_s();
        let b0 = entries[0].batch as f64;
        let b1 = entries[entries.len() - 1].batch as f64;
        println!(
            "\nbatch amortization: {:.0}x more queries for {:.2}x the step cost \
             (per-query cost ratio {:.3})",
            b1 / b0,
            last / first,
            (last / b1) / (first / b0)
        );
    }
}
