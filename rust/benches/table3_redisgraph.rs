//! Bench: the Table-III comparison — simulated adjusted speed-ups plus the
//! measured PJRT GraphBLAS engine throughput that anchors the Xeon model
//! (skipped when artifacts are absent).
//!
//! Knobs: PFQ_BENCH_SCALE (default 13) for the Pathfinder side.

use pathfinder_queries::baseline::GraphBlasEngine;
use pathfinder_queries::bench_harness::{table3, Harness};
use pathfinder_queries::config::experiment::ExperimentConfig;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::rmat::Rmat;
use pathfinder_queries::runtime::artifact::default_artifacts_dir;
use pathfinder_queries::runtime::Engine;
use pathfinder_queries::util::bench::{black_box, Bench};

fn main() {
    let scale: u32 = std::env::var("PFQ_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(13);
    let mut cfg = ExperimentConfig::default();
    cfg.workload.graph = GraphConfig::with_scale(scale);
    cfg.workload.query_counts = vec![128];
    cfg.workload.mixes.clear();
    cfg.results_dir = std::env::temp_dir().join("pfq-bench-results");
    let h = Harness::new(cfg).unwrap();

    // Simulated Table III (paper-anchored model).
    let data = table3::run(&h, None).unwrap();
    println!("table3 bench: scale {scale}");
    println!("{}", data.table().render());

    // Measured engine side (the real execution path behind the anchor).
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("(artifacts missing; engine measurement skipped — run `make artifacts`)");
        return;
    }
    let eng = match Engine::from_dir(&dir) {
        Ok(eng) => eng,
        Err(e) => {
            // Built without the `pjrt` feature: the stub engine refuses.
            println!("({e}; engine measurement skipped)");
            return;
        }
    };
    let n_art = eng.manifest().n;
    let gscale = (n_art as f64).log2() as u32;
    let gcfg = GraphConfig::with_scale(gscale);
    let small = build_undirected_csr(gcfg.n_vertices() as usize, &Rmat::new(gcfg).edges());
    let gb = GraphBlasEngine::new(&eng, &small).unwrap();
    let sources = pathfinder_queries::graph::sample::bfs_sources(&small, 32, 7);

    let mut bench = Bench::from_env();
    bench.run("pjrt/bfs x1", || black_box(gb.bfs(&sources[..1]).unwrap()));
    bench.run("pjrt/bfs x8 (one batch)", || black_box(gb.bfs(&sources[..8]).unwrap()));
    bench.run("pjrt/bfs x32 (one batch)", || black_box(gb.bfs(&sources[..32]).unwrap()));
    bench.run("pjrt/cc to convergence", || black_box(gb.cc().unwrap()));

    println!("\n== measured PJRT engine (artifact n={n_art}) ==");
    for r in bench.results() {
        println!("{}", r.report());
    }
    let x1 = bench.results()[0].median_s();
    let x32 = bench.results()[2].median_s();
    println!(
        "\nbatch efficiency: 32 queries in one batch cost {:.1}x one query \
         (ideal 1.0x if fully amortized, 32x if none)",
        x32 / x1
    );
}
