//! Bench: the Table-II mixed BFS+CC experiment — simulated improvement plus
//! the host cost of the CC demand cache (compute-once + rotate) vs naive
//! per-query recomputation.
//!
//! Knobs: PFQ_BENCH_SCALE (default 13).

use pathfinder_queries::alg::{Analysis, Cc};
use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::{GraphConfig, MixPoint};
use pathfinder_queries::coordinator::{planner, Coordinator, Policy};
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::rmat::Rmat;
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::util::bench::{black_box, Bench};
use pathfinder_queries::util::stats::improvement_pct;

fn main() {
    let scale: u32 = std::env::var("PFQ_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(13);
    let gcfg = GraphConfig::with_scale(scale);
    let g = build_undirected_csr(gcfg.n_vertices() as usize, &Rmat::new(gcfg).edges());
    let mix = MixPoint { bfs: 32, cc: 8 };
    println!(
        "table2 bench: scale {scale}, mix {}+{} on pathfinder-8\n",
        mix.bfs, mix.cc
    );

    let coord = Coordinator::new(&g, Machine::new(MachineConfig::pathfinder_8()));
    let m = coord.machine().clone();
    let queries = planner::mix_queries(&g, mix, 0xBF5);
    let seq_order = planner::sequential_mix_order(&queries);

    let mut bench = Bench::from_env();
    // End-to-end mixed experiment (prepare + both arms).
    bench.run("mixed/end-to-end (prepare+conc+seq)", || {
        let conc = coord.run(black_box(&queries), Policy::Concurrent).unwrap();
        let seq = coord.run(black_box(&seq_order), Policy::Sequential).unwrap();
        black_box((conc.makespan_s, seq.makespan_s))
    });

    // The per-kind demand cache: cached+rotated (what the coordinator does
    // for any analysis declaring `cacheable_demand`) vs recomputing the
    // functional CC per instance.
    bench.run("cc-demand/cached+rotate x8", || {
        let qs = pathfinder_queries::coordinator::planner::cc_queries(8);
        black_box(coord.prepare(&qs))
    });
    bench.run("cc-demand/recompute x8", || {
        (0..8)
            .map(|i| black_box(Cc.phases(g.view(), &m, i)))
            .collect::<Vec<_>>()
    });

    let conc = coord.run(&queries, Policy::Concurrent).unwrap();
    let seq = coord.run(&seq_order, Policy::Sequential).unwrap();
    println!(
        "\nsimulated: conc {:.4}s  seq {:.4}s  improvement {:.1}% (paper: ~70% on 8 nodes)\n",
        conc.makespan_s,
        seq.makespan_s,
        improvement_pct(seq.makespan_s, conc.makespan_s)
    );

    println!("== host wall times ==");
    for r in bench.results() {
        println!("{}", r.report());
    }
}
